//! Cross-artifact consistency rules (X2–X5).
//!
//! The determinism rules in [`super::rules`] look *into* Rust sources;
//! the rules here look *across* artifact boundaries, where drift is
//! silent because no compiler connects the two sides:
//!
//! - **X2** — every top-level config section parsed in
//!   `rust/src/config.rs` (`j.get("…")`) must be reachable from the CLI
//!   (`rust/src/main.rs` mentions it) and documented (DESIGN.md mentions
//!   it).
//! - **X3** — every `ext-*` experiment registered in
//!   `rust/src/experiments/mod.rs` must have a CI smoke step
//!   (`.github/workflows/ci.yml`) and a ROADMAP.md quickstart line.
//! - **X4** — every rule id in a `RULE_TABLE` declaration must have a
//!   `<rule>_bad.rs`/`<rule>_good.rs` fixture pair and a DESIGN.md §13
//!   table row (`| <id> |`).
//! - **X5** — every benchmark entry in a committed `BENCH_*.json` must
//!   name a bench case that still exists somewhere under `benches/`.
//!
//! Each check needs its paired artifact to exist: with the corresponding
//! [`Artifacts`] field absent the check is skipped, so in-memory fixture
//! scans (which pass [`Artifacts::default`]) never fire X-rules by
//! accident. These rules are not inline-suppressible — there is no
//! meaningful source line to hang a waiver on.

use std::fs;
use std::path::Path;

use super::parse::{ParsedFile, TokKind, Token};
use super::rules::Finding;

/// Non-Rust artifacts the cross-checks reconcile against. `None` (or an
/// empty list) means "artifact not available — skip that check".
#[derive(Debug, Default)]
pub struct Artifacts {
    /// DESIGN.md text (X2, X4).
    pub design: Option<String>,
    /// ROADMAP.md text (X3).
    pub roadmap: Option<String>,
    /// `.github/workflows/ci.yml` text (X3).
    pub ci: Option<String>,
    /// Committed `BENCH_*.json` baselines as (file name, contents) (X5).
    pub bench_baselines: Vec<(String, String)>,
    /// File names present in the lint fixture corpus directory (X4).
    pub fixtures: Option<Vec<String>>,
}

/// Load the artifact set from a repository checkout. Missing files are
/// simply absent (their checks are skipped), not errors — a pruned
/// checkout still lints.
pub fn load_artifacts(root: &Path) -> Artifacts {
    let read = |rel: &str| fs::read_to_string(root.join(rel)).ok();
    let mut bench_baselines = Vec::new();
    if let Ok(rd) = fs::read_dir(root) {
        let mut names: Vec<String> = rd
            .flatten()
            .map(|e| e.file_name().to_string_lossy().into_owned())
            .filter(|n| n.starts_with("BENCH_") && n.ends_with(".json"))
            .collect();
        names.sort();
        for name in names {
            if let Some(text) = read(&name) {
                bench_baselines.push((name, text));
            }
        }
    }
    let fixtures = fs::read_dir(root.join("rust/tests/lint_fixtures"))
        .ok()
        .map(|rd| {
            let mut names: Vec<String> = rd
                .flatten()
                .map(|e| e.file_name().to_string_lossy().into_owned())
                .collect();
            names.sort();
            names
        });
    Artifacts {
        design: read("DESIGN.md"),
        roadmap: read("ROADMAP.md"),
        ci: read(".github/workflows/ci.yml"),
        bench_baselines,
        fixtures,
    }
}

/// Run X2–X5 over the scanned file set against the artifact set.
pub fn cross_artifact_check(files: &[(String, String)], art: &Artifacts) -> Vec<Finding> {
    let mut findings = Vec::new();
    check_config_keys(files, art, &mut findings);
    check_experiments(files, art, &mut findings);
    check_rule_table(files, art, &mut findings);
    check_bench_baselines(files, art, &mut findings);
    findings
}

fn file_text<'a>(files: &'a [(String, String)], rel: &str) -> Option<&'a str> {
    files.iter().find(|(r, _)| r == rel).map(|(_, t)| t.as_str())
}

fn contains_ci(haystack: &str, needle: &str) -> bool {
    haystack.to_lowercase().contains(&needle.to_lowercase())
}

/// X2: top-level config keys (`j.get("key")` in config.rs) must surface
/// in main.rs (a CLI flag or its help text) and in DESIGN.md.
fn check_config_keys(files: &[(String, String)], art: &Artifacts, out: &mut Vec<Finding>) {
    const CONFIG: &str = "rust/src/config.rs";
    const MAIN: &str = "rust/src/main.rs";
    let (Some(config), Some(main), Some(design)) = (
        file_text(files, CONFIG),
        file_text(files, MAIN),
        art.design.as_deref(),
    ) else {
        return;
    };
    let pf = ParsedFile::parse(config);
    let src = pf.src.as_str();
    let mut seen: Vec<String> = Vec::new();
    for_sig_windows(&pf, 5, |w| {
        // `j . get ( "key"` — the receiver `j` is the root config object;
        // section handles (`s`, `e`, …) read nested keys, out of scope.
        if w[0].is_ident(src, "j")
            && w[1].is_punct(src, '.')
            && w[2].is_ident(src, "get")
            && w[3].is_punct(src, '(')
            && matches!(w[4].kind, TokKind::Str { .. })
        {
            let key = str_content(src, w[4]);
            if seen.contains(&key) {
                return;
            }
            seen.push(key.clone());
            let mut missing = Vec::new();
            if !contains_ci(main, &key) {
                missing.push("main.rs");
            }
            if !contains_ci(design, &key) {
                missing.push("DESIGN.md");
            }
            if !missing.is_empty() {
                out.push(Finding {
                    rule: "X2",
                    file: CONFIG.to_string(),
                    line: w[4].line + 1,
                    excerpt: format!("j.get(\"{key}\")"),
                    message: format!(
                        "config section `{key}` has no mention in {}",
                        missing.join(" or ")
                    ),
                });
            }
        }
    });
}

/// X3: every `ext-*` experiment id registered in experiments/mod.rs must
/// appear in the CI workflow (a smoke step) and in ROADMAP.md (the
/// quickstart block).
fn check_experiments(files: &[(String, String)], art: &Artifacts, out: &mut Vec<Finding>) {
    const REGISTRY: &str = "rust/src/experiments/mod.rs";
    let (Some(registry), Some(ci), Some(roadmap)) = (
        file_text(files, REGISTRY),
        art.ci.as_deref(),
        art.roadmap.as_deref(),
    ) else {
        return;
    };
    let pf = ParsedFile::parse(registry);
    let src = pf.src.as_str();
    for_sig_windows(&pf, 3, |w| {
        // `id : "ext-…"` — one registry entry.
        if !(w[0].is_ident(src, "id")
            && w[1].is_punct(src, ':')
            && matches!(w[2].kind, TokKind::Str { .. }))
        {
            return;
        }
        let id = str_content(src, w[2]);
        if !id.starts_with("ext-") {
            return;
        }
        let mut missing = Vec::new();
        if !ci.contains(&id) {
            missing.push("a ci.yml smoke step");
        }
        if !roadmap.contains(&id) {
            missing.push("a ROADMAP.md quickstart line");
        }
        if !missing.is_empty() {
            out.push(Finding {
                rule: "X3",
                file: REGISTRY.to_string(),
                line: w[2].line + 1,
                excerpt: format!("id: \"{id}\""),
                message: format!("experiment `{id}` is missing {}", missing.join(" and ")),
            });
        }
    });
}

/// X4: every rule id declared in a `RULE_TABLE: … = &[("id", …), …]`
/// must have a `<id>_bad.rs`/`<id>_good.rs` fixture pair and a
/// `| <id> |` row in DESIGN.md §13.
fn check_rule_table(files: &[(String, String)], art: &Artifacts, out: &mut Vec<Finding>) {
    let (Some(design), Some(fixtures)) = (art.design.as_deref(), art.fixtures.as_deref())
    else {
        return;
    };
    for (rel, text) in files {
        if !text.contains("RULE_TABLE") {
            continue;
        }
        let pf = ParsedFile::parse(text);
        let src = pf.src.as_str();
        for (k, &ti) in pf.sig.iter().enumerate() {
            let t = &pf.tokens[ti];
            // The *declaration* (`RULE_TABLE: … = &[`), not a use site
            // (`RULE_TABLE.iter()`) or an import (`…, RULE_TABLE};`).
            if !t.is_ident(src, "RULE_TABLE")
                || !pf
                    .sig
                    .get(k + 1)
                    .is_some_and(|&tj| pf.tokens[tj].is_punct(src, ':'))
            {
                continue;
            }
            // Find the opening `[` of the initializer.
            let Some(open_pos) = pf.sig[k..].iter().position(|&tj| {
                pf.tokens[tj].is_punct(src, '[')
            }) else {
                continue;
            };
            let open_ti = pf.sig[k + open_pos];
            let close_ti = pf.pairs.get(&open_ti).copied().unwrap_or(pf.tokens.len());
            // Each element is a paren group whose first string literal is
            // the rule id.
            let mut j = open_ti + 1;
            while j < close_ti {
                if pf.tokens[j].is_punct(src, '(') {
                    let elem_close = pf.pairs.get(&j).copied().unwrap_or(close_ti);
                    if let Some(id_tok) = pf.tokens[j + 1..elem_close]
                        .iter()
                        .find(|t| matches!(t.kind, TokKind::Str { .. }))
                    {
                        check_one_rule(rel, src, id_tok, design, fixtures, out);
                    }
                    j = elem_close + 1;
                } else {
                    j += 1;
                }
            }
        }
    }
}

fn check_one_rule(
    rel: &str,
    src: &str,
    id_tok: &Token,
    design: &str,
    fixtures: &[String],
    out: &mut Vec<Finding>,
) {
    let id = str_content(src, id_tok);
    if id.len() != 2 || !id.chars().all(|c| c.is_ascii_alphanumeric()) {
        return; // not a rule id — some other tuple table
    }
    let lower = id.to_lowercase();
    let bad = format!("{lower}_bad.rs");
    let good = format!("{lower}_good.rs");
    let mut missing = Vec::new();
    if !fixtures.iter().any(|f| f == &bad) {
        missing.push(bad.clone());
    }
    if !fixtures.iter().any(|f| f == &good) {
        missing.push(good.clone());
    }
    if !design.contains(&format!("| {id} |")) {
        missing.push("a DESIGN.md §13 row".to_string());
    }
    if !missing.is_empty() {
        out.push(Finding {
            rule: "X4",
            file: rel.to_string(),
            line: id_tok.line + 1,
            excerpt: format!("(\"{id}\", …)"),
            message: format!("rule {id} is missing {}", missing.join(", ")),
        });
    }
}

/// X5: every benchmark name recorded in a committed `BENCH_*.json` must
/// still exist as a case name in some `benches/*.rs` source.
fn check_bench_baselines(files: &[(String, String)], art: &Artifacts, out: &mut Vec<Finding>) {
    if art.bench_baselines.is_empty() {
        return;
    }
    // The set of string literals across the bench sources; bench case
    // names are always plain string literals passed to the harness.
    let mut names: Vec<String> = Vec::new();
    for (rel, text) in files {
        if !rel.starts_with("benches/") {
            continue;
        }
        let pf = ParsedFile::parse(text);
        let src = pf.src.as_str();
        for t in &pf.tokens {
            if matches!(t.kind, TokKind::Str { .. }) {
                names.push(str_content(src, t));
            }
        }
    }
    if names.is_empty() {
        return; // no bench sources in this file set — nothing to check
    }
    for (file, text) in &art.bench_baselines {
        let Ok(doc) = crate::util::json::Json::parse(text) else {
            out.push(Finding {
                rule: "X5",
                file: file.clone(),
                line: 1,
                excerpt: String::new(),
                message: format!("{file} is not valid JSON"),
            });
            continue;
        };
        for bench in doc.get("benchmarks").as_arr().unwrap_or(&[]) {
            let Some(name) = bench.get("name").as_str() else {
                continue;
            };
            if names.iter().any(|n| n == name) {
                continue;
            }
            let quoted = format!("\"{name}\"");
            let line = text
                .lines()
                .position(|l| l.contains(&quoted))
                .map(|p| p + 1)
                .unwrap_or(1);
            out.push(Finding {
                rule: "X5",
                file: file.clone(),
                line,
                excerpt: quoted,
                message: format!("bench `{name}` no longer exists under benches/"),
            });
        }
    }
}

/// Call `f` on every length-`n` window of significant tokens.
fn for_sig_windows<'a>(pf: &'a ParsedFile, n: usize, mut f: impl FnMut(&[&'a Token])) {
    if pf.sig.len() < n {
        return;
    }
    let toks: Vec<&Token> = pf.sig.iter().map(|&ti| &pf.tokens[ti]).collect();
    for w in toks.windows(n) {
        f(w);
    }
}

/// The content of a string-literal token (quotes stripped). Only plain
/// `"…"` literals appear in the shapes these rules match.
fn str_content(src: &str, t: &Token) -> String {
    let text = t.text(src);
    text.trim_start_matches('"').trim_end_matches('"').to_string()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn art_full() -> Artifacts {
        Artifacts {
            design: Some("## §13\n| D1 | … |\nmentions model and tiers keys".to_string()),
            roadmap: Some("andes exp ext-tiers\n".to_string()),
            ci: Some("run: andes exp ext-tiers --quick\n".to_string()),
            bench_baselines: vec![(
                "BENCH_x.json".to_string(),
                "{\"benchmarks\": [{\"name\": \"cal-pop/d=1\"}]}".to_string(),
            )],
            fixtures: Some(vec!["d1_bad.rs".to_string(), "d1_good.rs".to_string()]),
        }
    }

    #[test]
    fn default_artifacts_skip_every_check() {
        let files = vec![(
            "rust/src/config.rs".to_string(),
            "fn f(j: &Json) { j.get(\"ghost\"); }".to_string(),
        )];
        assert!(cross_artifact_check(&files, &Artifacts::default()).is_empty());
    }

    #[test]
    fn x2_fires_when_key_is_undocumented() {
        let files = vec![
            (
                "rust/src/config.rs".to_string(),
                "fn f(j: &Json) { j.get(\"model\"); j.get(\"ghost\"); }".to_string(),
            ),
            ("rust/src/main.rs".to_string(), "// --model flag".to_string()),
        ];
        let f = cross_artifact_check(&files, &art_full());
        let x2: Vec<&Finding> = f.iter().filter(|f| f.rule == "X2").collect();
        assert_eq!(x2.len(), 1);
        assert!(x2[0].message.contains("`ghost`"), "{}", x2[0].message);
        assert!(x2[0].message.contains("main.rs"));
        assert!(x2[0].message.contains("DESIGN.md"));
    }

    #[test]
    fn x3_fires_for_unsmoked_experiment() {
        let files = vec![(
            "rust/src/experiments/mod.rs".to_string(),
            "const R: &[E] = &[E { id: \"ext-tiers\" }, E { id: \"ext-ghost\" }, \
             E { id: \"fig2\" }];"
                .to_string(),
        )];
        let f = cross_artifact_check(&files, &art_full());
        let x3: Vec<&Finding> = f.iter().filter(|f| f.rule == "X3").collect();
        assert_eq!(x3.len(), 1);
        assert!(x3[0].message.contains("`ext-ghost`"));
    }

    #[test]
    fn x4_fires_for_rule_without_fixtures_or_row() {
        let files = vec![(
            "rust/src/analysis/rules.rs".to_string(),
            "pub const RULE_TABLE: &[(&str, &str)] = &[(\"D1\", \"x\"), (\"Z9\", \"ghost\")];\n\
             fn f() { RULE_TABLE.iter(); }"
                .to_string(),
        )];
        let f = cross_artifact_check(&files, &art_full());
        let x4: Vec<&Finding> = f.iter().filter(|f| f.rule == "X4").collect();
        assert_eq!(x4.len(), 1, "{f:?}");
        assert!(x4[0].message.contains("z9_bad.rs"), "{}", x4[0].message);
    }

    #[test]
    fn x5_fires_for_ghost_bench_entry() {
        let files = vec![(
            "benches/cal.rs".to_string(),
            "fn main() { run(\"cal-pop/d=1\"); }".to_string(),
        )];
        let mut art = art_full();
        art.bench_baselines = vec![(
            "BENCH_x.json".to_string(),
            "{\n \"benchmarks\": [\n  {\"name\": \"cal-pop/d=1\"},\n  \
             {\"name\": \"cal-ghost/d=9\"}\n ]\n}"
                .to_string(),
        )];
        let f = cross_artifact_check(&files, &art);
        let x5: Vec<&Finding> = f.iter().filter(|f| f.rule == "X5").collect();
        assert_eq!(x5.len(), 1);
        assert_eq!(x5[0].file, "BENCH_x.json");
        assert_eq!(x5[0].line, 4);
        assert!(x5[0].message.contains("cal-ghost"));
    }
}
