//! The determinism rule set (D1–D6) and the metric taxonomy cross-check
//! (X1). See DESIGN.md §13 for the rule table with rationale and fixes.
//!
//! Every rule matches against the *stripped* source from
//! [`super::lexer::strip_source`], so patterns inside comments or string
//! literals can never fire. Matching is token-ish string scanning, not a
//! parse: the rules are tuned to the idioms rustfmt actually produces in
//! this tree, and the fixture corpus in `rust/tests/lint_fixtures/` pins
//! both the positive and negative space.

use std::collections::BTreeMap;

use super::lexer::strip_source;
use super::suppress::{in_ranges, test_ranges, Suppressions};

/// Rule ids with one-line summaries, in report order.
pub const RULE_TABLE: &[(&str, &str)] = &[
    ("D1", "HashMap/HashSet iteration feeding output or simulation order"),
    ("D2", "wall-clock read outside wall-domain modules"),
    ("D3", "partial_cmp on floats in sorts/unwraps; use total_cmp"),
    ("D4", "unseeded randomness"),
    ("D5", "println!/eprintln! in library code; use log::"),
    ("D6", "unwrap()/expect() in simulation paths without lint:allow"),
    ("X1", "metric family declared/emitted mismatch"),
];

/// Is `id` a known rule id?
pub fn known_rule(id: &str) -> bool {
    RULE_TABLE.iter().any(|&(r, _)| r == id)
}

/// One lint finding, pointing at a 1-based source line.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Finding {
    pub rule: &'static str,
    pub file: String,
    pub line: usize,
    pub excerpt: String,
    pub message: String,
}

/// Declared vs emitted `andes_*` metric families, accumulated across
/// files and reconciled by [`cross_check`]. Maps family name to the
/// first site (file, 1-based line) that contributed it.
#[derive(Debug, Default)]
pub struct MetricUsage {
    pub declared: BTreeMap<String, (String, usize)>,
    pub emitted: BTreeMap<String, (String, usize)>,
}

/// Result of scanning one file.
#[derive(Debug, Default)]
pub struct ScanResult {
    pub findings: Vec<Finding>,
    /// Findings waived by inline `lint:allow` directives.
    pub suppressed: usize,
}

/// Module prefixes that legitimately read the wall clock (D2). These are
/// the wall-domain side of the clock split in DESIGN.md §12; everything
/// else must go through the engine `Clock`.
const WALL_ALLOW: &[&str] = &[
    "rust/src/server/",
    "rust/src/telemetry/",
    "rust/src/util/bench.rs",
];

/// Files allowed to print directly to stdout/stderr (D5).
const PRINT_ALLOW: &[&str] = &["rust/src/main.rs", "rust/src/telemetry/logging.rs"];

/// Library paths on the seeded simulation side where a panic corrupts an
/// experiment cell (D6). CLI/server/bench plumbing is out of scope. The
/// event calendar rides the `coordinator/` prefix; the shard runner is
/// listed explicitly because the rest of `experiments/` is CLI-side
/// report plumbing — but a panic on a grid worker kills every cell of
/// the run.
const D6_SCOPE: &[&str] = &[
    "rust/src/coordinator/",
    "rust/src/cluster/",
    "rust/src/gateway/",
    "rust/src/delivery/",
    "rust/src/qoe/",
    "rust/src/workload/",
    "rust/src/model/",
    "rust/src/backend/sim.rs",
    "rust/src/experiments/shard.rs",
    "rust/src/util/stats.rs",
    "rust/src/util/rng.rs",
];

/// Hash-collection methods whose call sites mean "iterate" (D1).
const ITER_METHODS: &[&str] = &[
    "iter",
    "iter_mut",
    "values",
    "values_mut",
    "keys",
    "drain",
    "into_iter",
    "retain",
];

/// Call tokens that emit a metric sample; a nearby `andes_*` string
/// literal names the family being emitted (X1).
const EMIT_TOKENS: &[&str] = &[
    ".inc(",
    ".set(",
    ".set_gauge(",
    ".observe(",
    ".observe_latency(",
    ".observe_tpot(",
    ".observe_unit(",
    "declare_counter(",
    "declare_gauge(",
    "declare_histogram(",
];

const D4_TOKENS: &[&str] = &["thread_rng", "from_entropy", "rand::random", "getrandom"];
const D5_TOKENS: &[&str] = &["println!", "eprintln!", "print!", "eprint!", "dbg!"];
const SORT_TOKENS: &[&str] = &[
    "sort_by(",
    "sort_unstable_by(",
    "sort_by_key(",
    "min_by(",
    "max_by(",
];

/// Scan one file. `rel` is the repo-relative path with `/` separators
/// (it selects per-path rule scopes); X1 family sightings are added to
/// `usage` for the cross-file reconciliation pass.
pub fn scan_source(rel: &str, text: &str, usage: &mut MetricUsage) -> ScanResult {
    let stripped = strip_source(text);
    let code = &stripped.code;
    let tranges = test_ranges(code);
    let mut sup = Suppressions::parse(&stripped);
    let raw_lines: Vec<&str> = text.split('\n').collect();
    let is_src = rel.starts_with("rust/src/");
    let mut findings = Vec::new();

    let mut emit = |rule: &'static str, li: usize, message: String, sup: &mut Suppressions| {
        if sup.allows(li, rule) {
            return;
        }
        let excerpt: String = raw_lines
            .get(li)
            .map(|l| l.trim().chars().take(120).collect())
            .unwrap_or_default();
        findings.push(Finding {
            rule,
            file: rel.to_string(),
            line: li + 1,
            excerpt,
            message,
        });
    };

    // D1: collect declared hash-collection names, then flag iteration.
    let mut hash_names: Vec<String> = Vec::new();
    for (li, line) in code.iter().enumerate() {
        if in_ranges(&tranges, li) {
            continue;
        }
        for name in hash_decl_names(line) {
            if !hash_names.contains(&name) {
                hash_names.push(name);
            }
        }
    }
    for (li, line) in code.iter().enumerate() {
        if in_ranges(&tranges, li) {
            continue;
        }
        for name in &hash_names {
            if iterates_hash(line, name) {
                let msg =
                    format!("hash iteration over `{name}`; use BTreeMap or sort at the emit site");
                emit("D1", li, msg, &mut sup);
                break;
            }
        }
    }

    // D2: wall-clock reads outside the wall domain.
    if !WALL_ALLOW.iter().any(|p| rel.starts_with(p)) {
        for (li, line) in code.iter().enumerate() {
            if line.contains("Instant::now") || line.contains("SystemTime") {
                let msg = "wall-clock read outside the wall domain; use the sim Clock";
                emit("D2", li, msg.to_string(), &mut sup);
            }
        }
    }

    // D3: partial_cmp feeding a sort or an unwrap. The unwrap may sit on
    // the next line after rustfmt wraps a long comparator, so look ahead
    // three lines; the sort adapter may sit up to two lines back.
    for (li, line) in code.iter().enumerate() {
        if !line.contains("partial_cmp") {
            continue;
        }
        let fwd = code[li..code.len().min(li + 3)].join("\n");
        let back = code[li.saturating_sub(2)..=li].join("\n");
        if fwd.contains(".unwrap()") || SORT_TOKENS.iter().any(|t| back.contains(t)) {
            let msg = "partial_cmp on floats panics or reorders on NaN; use f64::total_cmp";
            emit("D3", li, msg.to_string(), &mut sup);
        }
    }

    // D4: unseeded randomness, anywhere (tests included — a test seeded
    // from entropy cannot be rerun).
    for (li, line) in code.iter().enumerate() {
        if D4_TOKENS.iter().any(|t| line.contains(t)) {
            let msg = "unseeded randomness; use util::rng::Rng with an explicit seed";
            emit("D4", li, msg.to_string(), &mut sup);
        }
    }

    // D5: direct prints in library code.
    if is_src && !PRINT_ALLOW.contains(&rel) {
        for (li, line) in code.iter().enumerate() {
            if in_ranges(&tranges, li) {
                continue;
            }
            if D5_TOKENS.iter().any(|t| line.contains(t)) {
                let msg = "direct stdout/stderr print in library code; use log::";
                emit("D5", li, msg.to_string(), &mut sup);
            }
        }
    }

    // D6: unwrap/expect in seeded simulation paths.
    if D6_SCOPE.iter().any(|p| rel.starts_with(p)) {
        for (li, line) in code.iter().enumerate() {
            if in_ranges(&tranges, li) {
                continue;
            }
            let count = line.matches(".unwrap()").count() + line.matches(".expect(").count();
            for _ in 0..count {
                let msg = "unwrap/expect in a sim path; handle it or lint:allow(D6, reason)";
                emit("D6", li, msg.to_string(), &mut sup);
            }
        }
    }

    // X1 collection: record every `andes_*` family string next to an
    // emit token, split into declared (inside declare_base_families) vs
    // emitted (everywhere else in library code).
    if is_src {
        let decl_range = declare_fn_range(code);
        for lit in &stripped.strings {
            if !lit.content.starts_with("andes_") || in_ranges(&tranges, lit.line) {
                continue;
            }
            if !emit_token_nearby(code, lit.line, lit.col) {
                continue;
            }
            let in_decl = decl_range
                .map(|(a, b)| a <= lit.line && lit.line <= b)
                .unwrap_or(false);
            let target = if in_decl {
                &mut usage.declared
            } else {
                &mut usage.emitted
            };
            target
                .entry(lit.content.clone())
                .or_insert_with(|| (rel.to_string(), lit.line + 1));
        }
    }

    ScanResult {
        findings,
        suppressed: sup.hits(),
    }
}

/// Reconcile declared vs emitted metric families into X1 findings.
pub fn cross_check(usage: &MetricUsage) -> Vec<Finding> {
    let mut findings = Vec::new();
    for (fam, (file, line)) in &usage.emitted {
        if !usage.declared.contains_key(fam) {
            findings.push(Finding {
                rule: "X1",
                file: file.clone(),
                line: *line,
                excerpt: fam.clone(),
                message: format!("family `{fam}` is emitted but not declared"),
            });
        }
    }
    for (fam, (file, line)) in &usage.declared {
        if !usage.emitted.contains_key(fam) {
            findings.push(Finding {
                rule: "X1",
                file: file.clone(),
                line: *line,
                excerpt: fam.clone(),
                message: format!("family `{fam}` is declared but never emitted"),
            });
        }
    }
    findings
}

// --------------------------------------------------------------- D1 helpers

/// Names bound to `HashMap`/`HashSet` on this (stripped) line, via either
/// a struct-field/param type (`name: HashMap<...>`) or a constructor
/// binding (`name = HashMap::new()`).
fn hash_decl_names(line: &str) -> Vec<String> {
    let mut names = Vec::new();
    for key in ["HashMap<", "HashSet<", "HashMap::", "HashSet::"] {
        let mut from = 0;
        while let Some(rel_pos) = line[from..].find(key) {
            let pos = from + rel_pos;
            from = pos + key.len();
            let before = strip_suffix_path(&line[..pos]);
            let name = if key.ends_with('<') {
                // `name: HashMap<` (field or typed local).
                ident_before_char(before, ':')
            } else {
                // `name = HashMap::new()` — reject `==`, `<=`, etc.
                ident_before_char(before, '=').filter(|_| {
                    let t = before.trim_end();
                    !t.ends_with("==") && !t.ends_with("<=") && !t.ends_with(">=")
                })
            };
            if let Some(name) = name {
                if !names.contains(&name) {
                    names.push(name);
                }
            }
        }
    }
    names
}

/// Drop a trailing `std::collections::`-style path prefix so the
/// character before the type name can be inspected.
fn strip_suffix_path(s: &str) -> &str {
    let mut out = s;
    for p in ["std::collections::", "collections::", "std::"] {
        if let Some(t) = out.strip_suffix(p) {
            out = t;
        }
    }
    out
}

/// If `s` ends (modulo spaces) with `<sep>` preceded by an identifier,
/// return that identifier. `name: ` → Some("name") for sep ':'. Rejects
/// the path separator `::` when sep is ':'.
fn ident_before_char(s: &str, sep: char) -> Option<String> {
    let t = s.trim_end();
    let t = t.strip_suffix(sep)?;
    if sep == ':' && t.ends_with(':') {
        return None;
    }
    let t = t.trim_end();
    let ident: String = t
        .chars()
        .rev()
        .take_while(|c| c.is_alphanumeric() || *c == '_')
        .collect::<Vec<_>>()
        .into_iter()
        .rev()
        .collect();
    if ident.is_empty() || ident.chars().next().is_some_and(|c| c.is_ascii_digit()) {
        None
    } else {
        Some(ident)
    }
}

/// Does this line iterate the hash collection `name`? Matches
/// `name.iter()`-style calls (only bare `name` or `self.name` — a
/// `view.name` refers to some other binding) and `for … in …name` loops.
fn iterates_hash(line: &str, name: &str) -> bool {
    // Method form: name.<iter-method>(
    let mut from = 0;
    while let Some(rel_pos) = line[from..].find(name) {
        let pos = from + rel_pos;
        from = pos + name.len();
        if !receiver_boundary_ok(line, pos) {
            continue;
        }
        let after = &line[pos + name.len()..];
        let Some(rest) = after.strip_prefix('.') else {
            continue;
        };
        let method: String = rest
            .chars()
            .take_while(|c| c.is_alphanumeric() || *c == '_')
            .collect();
        if ITER_METHODS.contains(&method.as_str())
            && rest[method.len()..].trim_start().starts_with('(')
        {
            return true;
        }
    }
    // Loop form: for … in [&][mut ][self.]name<non-ident>
    if let Some(for_pos) = find_token(line, "for ") {
        if let Some(in_rel) = line[for_pos..].find(" in ") {
            let mut rhs = line[for_pos + in_rel + 4..].trim_start();
            rhs = rhs.strip_prefix('&').unwrap_or(rhs);
            rhs = rhs.strip_prefix("mut ").unwrap_or(rhs).trim_start();
            rhs = rhs.strip_prefix("self.").unwrap_or(rhs);
            if let Some(after) = rhs.strip_prefix(name) {
                let next = after.chars().next();
                if !matches!(next, Some(c) if c.is_alphanumeric() || c == '_' || c == '.') {
                    return true;
                }
            }
        }
    }
    false
}

/// The characters before a receiver occurrence must be either nothing,
/// a non-identifier character, or exactly `self.` — so `other.name.iter()`
/// never matches a field named `name`.
fn receiver_boundary_ok(line: &str, pos: usize) -> bool {
    let before = &line[..pos];
    match before.chars().next_back() {
        None => true,
        Some('.') => {
            let t = &before[..before.len() - 1];
            t.ends_with("self")
                && !t[..t.len() - 4]
                    .chars()
                    .next_back()
                    .is_some_and(|c| c.is_alphanumeric() || c == '_' || c == '.')
        }
        Some(c) => !(c.is_alphanumeric() || c == '_'),
    }
}

/// Find `token` at an identifier boundary (the char before must not be
/// part of an identifier).
fn find_token(line: &str, token: &str) -> Option<usize> {
    let mut from = 0;
    while let Some(rel_pos) = line[from..].find(token) {
        let pos = from + rel_pos;
        let ok = line[..pos]
            .chars()
            .next_back()
            .map(|c| !(c.is_alphanumeric() || c == '_'))
            .unwrap_or(true);
        if ok {
            return Some(pos);
        }
        from = pos + token.len();
    }
    None
}

// --------------------------------------------------------------- X1 helpers

/// The (inclusive, 0-based) line range of `fn declare_base_families`, if
/// this file defines it, via brace-depth tracking.
fn declare_fn_range(code: &[String]) -> Option<(usize, usize)> {
    let start = code
        .iter()
        .position(|l| l.contains("fn declare_base_families"))?;
    let mut depth: i64 = 0;
    let mut opened = false;
    for (li, line) in code.iter().enumerate().skip(start) {
        for c in line.chars() {
            if c == '{' {
                depth += 1;
                opened = true;
            } else if c == '}' {
                depth -= 1;
            }
        }
        if opened && depth == 0 {
            return Some((start, li));
        }
    }
    Some((start, code.len().saturating_sub(1)))
}

/// Is there an emit-call token on the literal's line before its column,
/// or on one of up to two continuation lines above it (rustfmt wraps
/// `registry.observe(` and the family name onto separate lines)?
fn emit_token_nearby(code: &[String], line: usize, col: usize) -> bool {
    for back in 0..3usize {
        let Some(li) = line.checked_sub(back) else {
            break;
        };
        let Some(lcode) = code.get(li) else {
            continue;
        };
        let limit = if back == 0 { col } else { lcode.len() };
        if EMIT_TOKENS
            .iter()
            .any(|t| lcode.find(t).is_some_and(|p| p <= limit))
        {
            return true;
        }
        // A non-continuation line above ends the lookback: the literal
        // belongs to whatever expression starts there.
        if back > 0 {
            let trimmed = lcode.trim_end();
            if !trimmed.is_empty() && !trimmed.ends_with('(') && !trimmed.ends_with(',') {
                break;
            }
        }
    }
    false
}

#[cfg(test)]
mod tests {
    use super::*;

    fn scan(rel: &str, text: &str) -> Vec<Finding> {
        let mut usage = MetricUsage::default();
        scan_source(rel, text, &mut usage).findings
    }

    #[test]
    fn d1_flags_iteration_not_lookup() {
        let src = "struct S { m: HashMap<u64, u32> }\nimpl S {\n fn f(&self) {\n  \
                   for (k, v) in &self.m {}\n  let _ = self.m.get(&1);\n } }";
        let f = scan("rust/src/x.rs", src);
        assert_eq!(f.iter().filter(|f| f.rule == "D1").count(), 1);
        assert_eq!(f[0].line, 4);
    }

    #[test]
    fn d1_respects_receiver_boundaries() {
        // `view.active` is not the declared `active` — no finding.
        let src = "struct S { active: HashSet<u64> }\nfn f(view: &View) { \
                   for id in view.active.iter() {} }";
        assert!(scan("rust/src/x.rs", src).is_empty());
        // But `self.active.iter()` and bare `active.iter()` are.
        let src2 = "struct S { active: HashSet<u64> }\nfn g(s: &S) { s.x(); }\n\
                    impl S { fn h(&self) { self.active.iter().count(); } }";
        assert_eq!(scan("rust/src/x.rs", src2).len(), 1);
    }

    #[test]
    fn d2_scoped_to_wall_domain() {
        let src = "fn f() { let t = Instant::now(); }";
        assert_eq!(scan("rust/src/coordinator/engine.rs", src).len(), 1);
        assert!(scan("rust/src/server/mod.rs", src).is_empty());
        assert!(scan("rust/src/util/bench.rs", src).is_empty());
    }

    #[test]
    fn d3_catches_wrapped_unwrap() {
        let src = "xs.sort_by(|a, b| {\n a.partial_cmp(b)\n  .unwrap()\n});";
        let f = scan("rust/src/x.rs", src);
        assert_eq!(f.iter().filter(|f| f.rule == "D3").count(), 1);
        // total_cmp is the fix and must not fire.
        assert!(scan("rust/src/x.rs", "xs.sort_by(|a, b| a.total_cmp(b));").is_empty());
    }

    #[test]
    fn d5_and_d6_skip_cfg_test_blocks() {
        let src = "fn lib() {}\n#[cfg(test)]\nmod tests {\n fn t() { println!(\"x\"); \
                   None::<u8>.unwrap(); }\n}";
        assert!(scan("rust/src/coordinator/x.rs", src).is_empty());
    }

    #[test]
    fn d6_suppression_with_reason() {
        let src = "fn f(v: &[u8]) {\n // lint:allow(D6, slice checked non-empty above)\n \
                   v.first().unwrap();\n}";
        let mut usage = MetricUsage::default();
        let r = scan_source("rust/src/coordinator/x.rs", src, &mut usage);
        assert!(r.findings.is_empty());
        assert_eq!(r.suppressed, 1);
    }

    #[test]
    fn x1_reconciles_declared_and_emitted() {
        let mut usage = MetricUsage::default();
        let decl = "fn declare_base_families(r: &mut Registry) {\n \
                    r.declare_counter(\"andes_a_total\");\n \
                    r.declare_gauge(\"andes_ghost\");\n}";
        scan_source("rust/src/telemetry/mod.rs", decl, &mut usage);
        let emit = "fn f(m: &Metrics) {\n m.inc(\"andes_a_total\", 1);\n \
                    m.inc(\"andes_rogue_total\", 1);\n}";
        scan_source("rust/src/gateway/mod.rs", emit, &mut usage);
        let x = cross_check(&usage);
        let msgs: Vec<&str> = x.iter().map(|f| f.excerpt.as_str()).collect();
        assert_eq!(msgs, vec!["andes_rogue_total", "andes_ghost"]);
    }

    #[test]
    fn strings_and_comments_never_fire() {
        let src = "// partial_cmp(a).unwrap() in a comment\n\
                   let s = \"Instant::now() thread_rng println!\";\n\
                   /* SystemTime */ fn f() {}";
        assert!(scan("rust/src/coordinator/x.rs", src).is_empty());
    }
}
