//! The determinism rule set (D1–D7), calendar-misuse rules (C1–C2),
//! suppression hygiene (W1), and the metric taxonomy cross-check (X1).
//! Cross-artifact rules (X2–X5) live in [`super::artifacts`]. See
//! DESIGN.md §13 for the rule table with rationale and fixes.
//!
//! Every file is parsed once by [`super::parse`] into a spanned token
//! stream plus its brace tree. Token-native rules (D2, D4–D7, C1, C2)
//! walk the stream directly — which is what lets D7 follow a wall-clock
//! value through `let` bindings across lines, and C1 associate a match
//! arm's payload decode with its `EventKind`. The line-oriented rules
//! (D1, D3, X1) still run on the stripped projection
//! ([`super::parse::to_stripped`]), which is byte-identical to the
//! legacy strip pass, so their behavior is unchanged. The fixture corpus
//! in `rust/tests/lint_fixtures/` pins both the positive and negative
//! space of every rule.

use std::collections::{BTreeMap, BTreeSet};

use super::parse::{to_stripped, ParsedFile, TokKind, Token};
use super::suppress::{in_ranges, test_ranges, Suppressions};

/// Rule ids with one-line summaries, in report order.
pub const RULE_TABLE: &[(&str, &str)] = &[
    ("D1", "HashMap/HashSet iteration feeding output or simulation order"),
    ("D2", "wall-clock read outside wall-domain modules, or env read on a sim path"),
    ("D3", "partial_cmp on floats in sorts/unwraps; use total_cmp"),
    ("D4", "unseeded randomness"),
    ("D5", "println!/eprintln! in library code; use log::"),
    ("D6", "unwrap()/expect() in simulation paths without lint:allow"),
    ("D7", "wall-clock value flowing into sim-time arithmetic or a sim-path call"),
    ("C1", "calendar payload to_bits/from_bits encode-decode mismatch"),
    ("C2", "sim clock field mutated outside coordinator/"),
    ("W1", "lint:allow directive that waived no finding"),
    ("X1", "metric family declared/emitted mismatch"),
    ("X2", "config key without a main.rs CLI surface or DESIGN.md mention"),
    ("X3", "experiment without a CI smoke step or ROADMAP quickstart line"),
    ("X4", "lint rule without a fixture pair or DESIGN.md §13 row"),
    ("X5", "BENCH_*.json entry naming a bench that no longer exists"),
];

/// Is `id` a known rule id?
pub fn known_rule(id: &str) -> bool {
    RULE_TABLE.iter().any(|&(r, _)| r == id)
}

/// One lint finding, pointing at a 1-based source line.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Finding {
    pub rule: &'static str,
    pub file: String,
    pub line: usize,
    pub excerpt: String,
    pub message: String,
}

/// Declared vs emitted `andes_*` metric families, accumulated across
/// files and reconciled by [`cross_check`]. Maps family name to the
/// first site (file, 1-based line) that contributed it.
#[derive(Debug, Default)]
pub struct MetricUsage {
    pub declared: BTreeMap<String, (String, usize)>,
    pub emitted: BTreeMap<String, (String, usize)>,
}

/// Calendar payload-encoding evidence, accumulated across files and
/// reconciled by [`cross_check`] into C1 findings. Keyed by `EventKind`
/// variant name; each site records whether it used the bits encoding
/// (`to_bits` at a register, `from_bits` at a decode) plus (file,
/// 1-based line).
#[derive(Debug, Default)]
pub struct CalendarUsage {
    pub registers: BTreeMap<String, Vec<(bool, String, usize)>>,
    pub decodes: BTreeMap<String, Vec<(bool, String, usize)>>,
}

/// All cross-file evidence a scan accumulates for the reconciliation
/// pass: the X1 metric taxonomy and the C1 calendar payload protocol.
#[derive(Debug, Default)]
pub struct CrossUsage {
    pub metrics: MetricUsage,
    pub calendar: CalendarUsage,
}

/// Result of scanning one file.
#[derive(Debug, Default)]
pub struct ScanResult {
    pub findings: Vec<Finding>,
    /// Findings waived by inline `lint:allow` directives.
    pub suppressed: usize,
}

/// Module prefixes that legitimately read the wall clock (D2/D7). These
/// are the wall-domain side of the clock split in DESIGN.md §12;
/// everything else must go through the engine `Clock`. D7 still applies
/// *inside* the wall domain: even there, a wall value must not reach
/// sim-time arithmetic.
const WALL_ALLOW: &[&str] = &[
    "rust/src/server/",
    "rust/src/telemetry/",
    "rust/src/util/bench.rs",
];

/// Files allowed to print directly to stdout/stderr (D5).
const PRINT_ALLOW: &[&str] = &["rust/src/main.rs", "rust/src/telemetry/logging.rs"];

/// Library paths on the seeded simulation side where a panic corrupts an
/// experiment cell (D6). CLI/server/bench plumbing is out of scope. The
/// event calendar rides the `coordinator/` prefix; the shard runner is
/// listed explicitly because the rest of `experiments/` is CLI-side
/// report plumbing — but a panic on a grid worker kills every cell of
/// the run.
const D6_SCOPE: &[&str] = &[
    "rust/src/coordinator/",
    "rust/src/cluster/",
    "rust/src/gateway/",
    "rust/src/delivery/",
    "rust/src/qoe/",
    "rust/src/workload/",
    "rust/src/model/",
    "rust/src/backend/sim.rs",
    "rust/src/experiments/shard.rs",
    "rust/src/util/stats.rs",
    "rust/src/util/rng.rs",
];

/// Sim paths where a direct clock-field mutation (C2) bypasses the event
/// calendar. `coordinator/` is deliberately absent: the calendar and the
/// engine it drives are the sanctioned mutation sites.
const C2_SCOPE: &[&str] = &[
    "rust/src/cluster/",
    "rust/src/gateway/",
    "rust/src/delivery/",
    "rust/src/qoe/",
    "rust/src/workload/",
    "rust/src/model/",
    "rust/src/backend/sim.rs",
    "rust/src/experiments/shard.rs",
];

/// Hash-collection methods whose call sites mean "iterate" (D1).
const ITER_METHODS: &[&str] = &[
    "iter",
    "iter_mut",
    "values",
    "values_mut",
    "keys",
    "drain",
    "into_iter",
    "retain",
];

/// Call tokens that emit a metric sample; a nearby `andes_*` string
/// literal names the family being emitted (X1).
const EMIT_TOKENS: &[&str] = &[
    ".inc(",
    ".set(",
    ".set_gauge(",
    ".observe(",
    ".observe_latency(",
    ".observe_tpot(",
    ".observe_unit(",
    "declare_counter(",
    "declare_gauge(",
    "declare_histogram(",
];

const D4_IDENTS: &[&str] = &["thread_rng", "from_entropy", "getrandom"];
const D5_MACROS: &[&str] = &["println", "eprintln", "print", "eprint", "dbg"];
const SORT_TOKENS: &[&str] = &[
    "sort_by(",
    "sort_unstable_by(",
    "sort_by_key(",
    "min_by(",
    "max_by(",
];

/// Sim-path entry points whose arguments are simulation times (D7 sink
/// A): a tainted wall-clock value passed into one of these launders a
/// wall read into the deterministic timeline.
const D7_SINKS: &[&str] = &[
    "register",
    "advance",
    "advance_to",
    "schedule",
    "step_until",
    "run_until",
];

/// Duration-to-number conversions: the moment a wall `Duration` becomes
/// arithmetic-ready (D7 sink B).
const D7_DUR_CONV: &[&str] = &[
    "as_secs_f64",
    "as_secs_f32",
    "as_millis",
    "as_micros",
    "as_nanos",
];

/// Scan one file. `rel` is the repo-relative path with `/` separators
/// (it selects per-path rule scopes); X1 family sightings and C1
/// calendar payload evidence are added to `usage` for the cross-file
/// reconciliation pass.
pub fn scan_source(rel: &str, text: &str, usage: &mut CrossUsage) -> ScanResult {
    let pf = ParsedFile::parse(text);
    let stripped = to_stripped(text, &pf.tokens);
    let code = &stripped.code;
    let tranges = test_ranges(code);
    let mut sup = Suppressions::parse(&stripped);
    let raw_lines: Vec<&str> = text.split('\n').collect();
    let is_src = rel.starts_with("rust/src/");
    let mut findings = Vec::new();

    let mut emit = |rule: &'static str, li: usize, message: String, sup: &mut Suppressions| {
        if sup.allows(li, rule) {
            return;
        }
        let excerpt: String = raw_lines
            .get(li)
            .map(|l| l.trim().chars().take(120).collect())
            .unwrap_or_default();
        findings.push(Finding {
            rule,
            file: rel.to_string(),
            line: li + 1,
            excerpt,
            message,
        });
    };

    // D1: collect declared hash-collection names, then flag iteration.
    let mut hash_names: Vec<String> = Vec::new();
    for (li, line) in code.iter().enumerate() {
        if in_ranges(&tranges, li) {
            continue;
        }
        for name in hash_decl_names(line) {
            if !hash_names.contains(&name) {
                hash_names.push(name);
            }
        }
    }
    for (li, line) in code.iter().enumerate() {
        if in_ranges(&tranges, li) {
            continue;
        }
        for name in &hash_names {
            if iterates_hash(line, name) {
                let msg =
                    format!("hash iteration over `{name}`; use BTreeMap or sort at the emit site");
                emit("D1", li, msg, &mut sup);
                break;
            }
        }
    }

    let src = pf.src.as_str();
    let sig_tok = |k: usize| pf.sig.get(k).map(|&ti| &pf.tokens[ti]);

    // D2: wall-clock reads outside the wall domain (one finding per
    // line, like the strip-pass predecessor).
    if !WALL_ALLOW.iter().any(|p| rel.starts_with(p)) {
        let mut fired_lines: BTreeSet<usize> = BTreeSet::new();
        for (k, &ti) in pf.sig.iter().enumerate() {
            let t = &pf.tokens[ti];
            if t.kind != TokKind::Ident {
                continue;
            }
            let hit = match t.text(src) {
                "SystemTime" => true,
                "Instant" => {
                    sig_tok(k + 1).is_some_and(|t| t.is_punct(src, ':'))
                        && sig_tok(k + 2).is_some_and(|t| t.is_punct(src, ':'))
                        && sig_tok(k + 3)
                            .is_some_and(|t| t.kind == TokKind::Ident && t.text(src).starts_with("now"))
                }
                _ => false,
            };
            if hit && fired_lines.insert(t.line) {
                let msg = "wall-clock read outside the wall domain; use the sim Clock";
                emit("D2", t.line, msg.to_string(), &mut sup);
            }
        }
    }

    // D2 (env-var case): environment reads on seeded simulation paths.
    // `std::env::var` on a sim hot path is a wall-environment dependency
    // that can flip behavior between otherwise-identical runs (the
    // `ANDES_TRACE_CAP` regression in sched/andes.rs). Scoped like D6 to
    // the sim-side library paths; benches, tests, and the golden/bench
    // bless knobs live outside that scope.
    if D6_SCOPE.iter().any(|p| rel.starts_with(p)) {
        let mut fired_lines: BTreeSet<usize> = BTreeSet::new();
        for (k, &ti) in pf.sig.iter().enumerate() {
            let t = &pf.tokens[ti];
            if t.kind != TokKind::Ident
                || t.text(src) != "env"
                || in_ranges(&tranges, t.line)
            {
                continue;
            }
            let hit = sig_tok(k + 1).is_some_and(|t| t.is_punct(src, ':'))
                && sig_tok(k + 2).is_some_and(|t| t.is_punct(src, ':'))
                && sig_tok(k + 3).is_some_and(|t| {
                    t.kind == TokKind::Ident
                        && matches!(t.text(src), "var" | "var_os" | "vars" | "vars_os")
                });
            if hit && fired_lines.insert(t.line) {
                let msg = "environment read on a sim path; hoist to config or \
                           gate on log_enabled!";
                emit("D2", t.line, msg.to_string(), &mut sup);
            }
        }
    }

    // D3: partial_cmp feeding a sort or an unwrap. The unwrap may sit on
    // the next line after rustfmt wraps a long comparator, so look ahead
    // three lines; the sort adapter may sit up to two lines back.
    for (li, line) in code.iter().enumerate() {
        if !line.contains("partial_cmp") {
            continue;
        }
        let fwd = code[li..code.len().min(li + 3)].join("\n");
        let back = code[li.saturating_sub(2)..=li].join("\n");
        if fwd.contains(".unwrap()") || SORT_TOKENS.iter().any(|t| back.contains(t)) {
            let msg = "partial_cmp on floats panics or reorders on NaN; use f64::total_cmp";
            emit("D3", li, msg.to_string(), &mut sup);
        }
    }

    // D4: unseeded randomness, anywhere (tests included — a test seeded
    // from entropy cannot be rerun). One finding per line.
    {
        let mut fired_lines: BTreeSet<usize> = BTreeSet::new();
        for (k, &ti) in pf.sig.iter().enumerate() {
            let t = &pf.tokens[ti];
            if t.kind != TokKind::Ident {
                continue;
            }
            let name = t.text(src);
            let hit = D4_IDENTS.contains(&name)
                || (name == "rand"
                    && sig_tok(k + 1).is_some_and(|t| t.is_punct(src, ':'))
                    && sig_tok(k + 2).is_some_and(|t| t.is_punct(src, ':'))
                    && sig_tok(k + 3).is_some_and(|t| t.is_ident(src, "random")));
            if hit && fired_lines.insert(t.line) {
                let msg = "unseeded randomness; use util::rng::Rng with an explicit seed";
                emit("D4", t.line, msg.to_string(), &mut sup);
            }
        }
    }

    // D5: direct prints in library code. One finding per line.
    if is_src && !PRINT_ALLOW.contains(&rel) {
        let mut fired_lines: BTreeSet<usize> = BTreeSet::new();
        for (k, &ti) in pf.sig.iter().enumerate() {
            let t = &pf.tokens[ti];
            if t.kind != TokKind::Ident
                || !D5_MACROS.contains(&t.text(src))
                || !sig_tok(k + 1).is_some_and(|t| t.is_punct(src, '!'))
                || in_ranges(&tranges, t.line)
            {
                continue;
            }
            if fired_lines.insert(t.line) {
                let msg = "direct stdout/stderr print in library code; use log::";
                emit("D5", t.line, msg.to_string(), &mut sup);
            }
        }
    }

    // D6: unwrap/expect in seeded simulation paths. Per occurrence, like
    // the strip-pass predecessor's per-line substring count.
    if D6_SCOPE.iter().any(|p| rel.starts_with(p)) {
        for (k, &ti) in pf.sig.iter().enumerate() {
            let t = &pf.tokens[ti];
            if !t.is_punct(src, '.') || in_ranges(&tranges, t.line) {
                continue;
            }
            let Some(name_tok) = sig_tok(k + 1) else { continue };
            let hit = match name_tok.text(src) {
                "unwrap" => {
                    sig_tok(k + 2).is_some_and(|t| t.is_punct(src, '('))
                        && sig_tok(k + 3).is_some_and(|t| t.is_punct(src, ')'))
                }
                "expect" => sig_tok(k + 2).is_some_and(|t| t.is_punct(src, '(')),
                _ => false,
            };
            if hit {
                let msg = "unwrap/expect in a sim path; handle it or lint:allow(D6, reason)";
                emit("D6", name_tok.line, msg.to_string(), &mut sup);
            }
        }
    }

    // D7: binding-aware wall-clock flow. Applies on every path — the
    // wall domain may *read* the clock (D2 allows it there) but must not
    // mix the value into sim-time arithmetic either.
    for (li, msg) in d7_scan(&pf) {
        emit("D7", li, msg, &mut sup);
    }

    // C2: direct mutation of a sim clock binding outside coordinator/.
    if C2_SCOPE.iter().any(|p| rel.starts_with(p)) {
        for (k, &ti) in pf.sig.iter().enumerate() {
            let t = &pf.tokens[ti];
            if t.kind != TokKind::Ident
                || !matches!(t.text(src), "now" | "sim_now")
                || in_ranges(&tranges, t.line)
            {
                continue;
            }
            if k > 0
                && sig_tok(k - 1)
                    .is_some_and(|p| p.kind == TokKind::Ident && matches!(p.text(src), "let" | "mut"))
            {
                continue; // a fresh binding, not a mutation
            }
            let Some(n1) = sig_tok(k + 1) else { continue };
            let n2 = sig_tok(k + 2);
            let plain_assign = n1.is_punct(src, '=')
                && !n2.is_some_and(|t| t.is_punct(src, '=') || t.is_punct(src, '>'));
            let compound_assign = matches!(n1.text(src), "+" | "-" | "*" | "/")
                && n1.kind == TokKind::Punct
                && n2.is_some_and(|t| t.is_punct(src, '=') && n1.hi == t.lo);
            if plain_assign || compound_assign {
                let msg = format!(
                    "direct `{}` mutation outside coordinator/; advance time via the event calendar",
                    t.text(src)
                );
                emit("C2", t.line, msg, &mut sup);
            }
        }
    }

    // C1 collection: register/decode sites with their EventKind and
    // whether the payload went through the bits encoding.
    if is_src {
        c1_collect(&pf, rel, &tranges, &mut usage.calendar);
    }

    // X1 collection: record every `andes_*` family string next to an
    // emit token, split into declared (inside declare_base_families) vs
    // emitted (everywhere else in library code).
    if is_src {
        let decl_range = declare_fn_range(code);
        for lit in &stripped.strings {
            if !lit.content.starts_with("andes_") || in_ranges(&tranges, lit.line) {
                continue;
            }
            if !emit_token_nearby(code, lit.line, lit.col) {
                continue;
            }
            let in_decl = decl_range
                .map(|(a, b)| a <= lit.line && lit.line <= b)
                .unwrap_or(false);
            let target = if in_decl {
                &mut usage.metrics.declared
            } else {
                &mut usage.metrics.emitted
            };
            target
                .entry(lit.content.clone())
                .or_insert_with(|| (rel.to_string(), lit.line + 1));
        }
    }

    // W1: every directive above consulted its lines through `allows`;
    // whatever remains unused is a stale waiver.
    for (li, rule) in sup.unused() {
        let excerpt: String = raw_lines
            .get(li)
            .map(|l| l.trim().chars().take(120).collect())
            .unwrap_or_default();
        findings.push(Finding {
            rule: "W1",
            file: rel.to_string(),
            line: li + 1,
            excerpt,
            message: format!("unused suppression: lint:allow({rule}) waived no finding"),
        });
    }

    ScanResult {
        findings,
        suppressed: sup.hits(),
    }
}

/// Reconcile the cross-file evidence: declared vs emitted metric
/// families (X1) and calendar payload encode/decode protocol (C1).
pub fn cross_check(usage: &CrossUsage) -> Vec<Finding> {
    let mut findings = Vec::new();
    for (fam, (file, line)) in &usage.metrics.emitted {
        if !usage.metrics.declared.contains_key(fam) {
            findings.push(Finding {
                rule: "X1",
                file: file.clone(),
                line: *line,
                excerpt: fam.clone(),
                message: format!("family `{fam}` is emitted but not declared"),
            });
        }
    }
    for (fam, (file, line)) in &usage.metrics.declared {
        if !usage.metrics.emitted.contains_key(fam) {
            findings.push(Finding {
                rule: "X1",
                file: file.clone(),
                line: *line,
                excerpt: fam.clone(),
                message: format!("family `{fam}` is declared but never emitted"),
            });
        }
    }
    for (kind, regs) in &usage.calendar.registers {
        let any_enc = regs.iter().any(|&(enc, _, _)| enc);
        let any_raw = regs.iter().any(|&(enc, _, _)| !enc);
        if any_enc && any_raw {
            for (_, file, line) in regs.iter().filter(|&&(enc, _, _)| !enc) {
                findings.push(Finding {
                    rule: "C1",
                    file: file.clone(),
                    line: *line,
                    excerpt: format!("EventKind::{kind}"),
                    message: format!(
                        "payload for EventKind::{kind} is f64::to_bits-encoded elsewhere \
                         but registered raw here"
                    ),
                });
            }
        }
        for (decoded, file, line) in usage.calendar.decodes.get(kind).into_iter().flatten() {
            if any_enc && !decoded {
                findings.push(Finding {
                    rule: "C1",
                    file: file.clone(),
                    line: *line,
                    excerpt: format!("EventKind::{kind}"),
                    message: format!(
                        "payload for EventKind::{kind} is f64::to_bits-encoded; \
                         decode it with f64::from_bits"
                    ),
                });
            } else if !any_enc && *decoded {
                findings.push(Finding {
                    rule: "C1",
                    file: file.clone(),
                    line: *line,
                    excerpt: format!("EventKind::{kind}"),
                    message: format!(
                        "payload for EventKind::{kind} is a raw id; \
                         f64::from_bits here decodes garbage"
                    ),
                });
            }
        }
    }
    findings
}

// --------------------------------------------------------------- D7 engine

/// Binding-aware wall-clock flow, scoped per brace-tree block. Taint
/// enters at a `let` whose statement mentions `Instant`/`SystemTime`
/// (constructor call or type ascription) or at a typed fn param, then
/// propagates one statement at a time through further `let` bindings.
/// A finding fires when a tainted identifier (A) appears inside the
/// argument list of a sim-path sink ([`D7_SINKS`]) or (B) shares a
/// statement with a duration conversion, a binary arithmetic operator,
/// and a sim-time identifier (`now`/`sim*`).
fn d7_scan(pf: &ParsedFile) -> Vec<(usize, String)> {
    let src = pf.src.as_str();
    let mut out = Vec::new();
    let mut scopes: Vec<BTreeMap<String, usize>> = vec![BTreeMap::new()];
    let mut pending_fn: BTreeMap<String, usize> = BTreeMap::new();
    let mut stmt: Vec<usize> = Vec::new(); // sig positions of the current statement

    let mut k = 0usize;
    while k < pf.sig.len() {
        let t = &pf.tokens[pf.sig[k]];
        if t.kind == TokKind::Punct {
            match t.text(src).chars().next() {
                Some('{') => {
                    d7_flush(pf, &stmt, &mut scopes, &mut out);
                    stmt.clear();
                    scopes.push(std::mem::take(&mut pending_fn));
                }
                Some('}') => {
                    d7_flush(pf, &stmt, &mut scopes, &mut out);
                    stmt.clear();
                    if scopes.len() > 1 {
                        scopes.pop();
                    }
                }
                Some(';') => {
                    d7_flush(pf, &stmt, &mut scopes, &mut out);
                    stmt.clear();
                }
                _ => stmt.push(k),
            }
        } else {
            if t.is_ident(src, "fn") {
                pending_fn = d7_fn_param_taints(pf, k);
            }
            stmt.push(k);
        }
        k += 1;
    }
    d7_flush(pf, &stmt, &mut scopes, &mut out);
    out
}

/// Typed wall-clock fn params: `fn f(t0: Instant, …)` taints `t0` for
/// the function body about to open.
fn d7_fn_param_taints(pf: &ParsedFile, fn_pos: usize) -> BTreeMap<String, usize> {
    let src = pf.src.as_str();
    let mut taints = BTreeMap::new();
    // Find the parameter list: the first `(` within a few tokens of `fn`.
    let mut open_pos = None;
    for j in fn_pos + 1..(fn_pos + 8).min(pf.sig.len()) {
        if pf.tokens[pf.sig[j]].is_punct(src, '(') {
            open_pos = Some(j);
            break;
        }
    }
    let Some(open_pos) = open_pos else {
        return taints;
    };
    let open_ti = pf.sig[open_pos];
    let Some(&close_ti) = pf.pairs.get(&open_ti) else {
        return taints;
    };
    // Split the parameter region at top-level commas.
    let mut depth = 0usize;
    let mut param: Vec<&Token> = Vec::new();
    let mut flush_param = |param: &mut Vec<&Token>, taints: &mut BTreeMap<String, usize>| {
        let wall = param
            .iter()
            .any(|t| t.kind == TokKind::Ident && matches!(t.text(src), "Instant" | "SystemTime"));
        if wall {
            if let Some(name) = param
                .iter()
                .find(|t| t.kind == TokKind::Ident && !matches!(t.text(src), "mut" | "self"))
            {
                taints.insert(name.text(src).to_string(), name.line);
            }
        }
        param.clear();
    };
    for j in open_pos + 1..pf.sig.len() {
        let ti = pf.sig[j];
        if ti >= close_ti {
            break;
        }
        let t = &pf.tokens[ti];
        if t.kind == TokKind::Punct {
            match t.text(src).chars().next() {
                Some('(' | '[' | '{') => depth += 1,
                Some(')' | ']' | '}') => depth = depth.saturating_sub(1),
                Some(',') if depth == 0 => {
                    flush_param(&mut param, &mut taints);
                    continue;
                }
                _ => {}
            }
        }
        param.push(t);
    }
    flush_param(&mut param, &mut taints);
    taints
}

/// Analyze one buffered statement: update taint bindings, then check the
/// two sink shapes.
fn d7_flush(
    pf: &ParsedFile,
    stmt: &[usize],
    scopes: &mut [BTreeMap<String, usize>],
    out: &mut Vec<(usize, String)>,
) {
    if stmt.is_empty() {
        return;
    }
    let src = pf.src.as_str();
    let toks: Vec<&Token> = stmt.iter().map(|&k| &pf.tokens[pf.sig[k]]).collect();
    let tainted_at = |name: &str, scopes: &[BTreeMap<String, usize>]| -> Option<usize> {
        scopes.iter().rev().find_map(|s| s.get(name).copied())
    };

    // Sink A: a tainted ident inside a sim sink's argument list.
    let mut fired = false;
    for (j, t) in toks.iter().enumerate() {
        if t.kind != TokKind::Ident || !D7_SINKS.contains(&t.text(src)) {
            continue;
        }
        if j > 0 && toks[j - 1].is_ident(src, "fn") {
            continue; // a declaration, not a call
        }
        if !toks.get(j + 1).is_some_and(|t| t.is_punct(src, '(')) {
            continue;
        }
        // Argument region: to the matching close, or the statement's end
        // if a block boundary cut the buffer short.
        let mut depth = 0usize;
        for arg in &toks[j + 1..] {
            match (arg.kind, arg.text(src).chars().next()) {
                (TokKind::Punct, Some('(' | '[' | '{')) => depth += 1,
                (TokKind::Punct, Some(')' | ']' | '}')) => {
                    if depth <= 1 {
                        break;
                    }
                    depth -= 1;
                }
                _ => {}
            }
            if arg.kind == TokKind::Ident {
                if let Some(bound) = tainted_at(arg.text(src), scopes) {
                    out.push((
                        arg.line,
                        format!(
                            "wall-clock value `{}` (bound at line {}) passed to sim-path \
                             `{}`; derive sim times from the calendar instead",
                            arg.text(src),
                            bound + 1,
                            t.text(src)
                        ),
                    ));
                    fired = true;
                    break;
                }
            }
        }
    }

    // Sink B: tainted ident + duration conversion + binary arithmetic +
    // a sim-time ident, all in one statement.
    if !fired {
        let tainted_tok = toks
            .iter()
            .find(|t| t.kind == TokKind::Ident && tainted_at(t.text(src), scopes).is_some());
        let has_conv = toks
            .iter()
            .any(|t| t.kind == TokKind::Ident && D7_DUR_CONV.contains(&t.text(src)));
        // Binary arithmetic: the operator must follow a value (ident,
        // number, or closing paren) so unary minus and `->` stay out.
        let has_arith = toks.iter().enumerate().any(|(j, t)| {
            t.kind == TokKind::Punct
                && matches!(t.text(src), "+" | "-" | "*" | "/")
                && !toks.get(j + 1).is_some_and(|n| n.is_punct(src, '>')) // `->`
                && j > 0
                && (matches!(toks[j - 1].kind, TokKind::Ident | TokKind::Num)
                    || toks[j - 1].is_punct(src, ')'))
        });
        let sim_ident = toks.iter().any(|t| {
            t.kind == TokKind::Ident
                && (matches!(t.text(src), "now" | "sim" | "sim_now")
                    || t.text(src).starts_with("sim_"))
                && tainted_at(t.text(src), scopes).is_none()
        });
        if let Some(t) = tainted_tok {
            if has_conv && has_arith && sim_ident {
                let bound = tainted_at(t.text(src), scopes).unwrap_or(t.line);
                out.push((
                    t.line,
                    format!(
                        "wall-clock value `{}` (bound at line {}) mixed into sim-time \
                         arithmetic; keep wall and sim clocks in separate domains",
                        t.text(src),
                        bound + 1
                    ),
                ));
            }
        }
    }

    // Binding update last: `let x = …` taints `x` for *subsequent*
    // statements (the binding statement itself was analyzed above).
    if toks.first().is_some_and(|t| t.is_ident(src, "let")) {
        let name = toks
            .iter()
            .skip(1)
            .take_while(|t| !t.is_punct(src, '=') && !t.is_punct(src, ':'))
            .find(|t| t.kind == TokKind::Ident && !t.is_ident(src, "mut"));
        if let Some(name_tok) = name {
            let wall_source = toks
                .iter()
                .any(|t| t.kind == TokKind::Ident && matches!(t.text(src), "Instant" | "SystemTime"));
            let tainted_src = toks
                .iter()
                .skip(1)
                .any(|t| {
                    t.kind == TokKind::Ident
                        && t.lo != name_tok.lo
                        && tainted_at(t.text(src), scopes).is_some()
                });
            let scope = scopes.last_mut().expect("scope stack non-empty");
            if wall_source || tainted_src {
                scope.insert(name_tok.text(src).to_string(), name_tok.line);
            } else {
                // A rebinding from a clean source clears older taint.
                scope.remove(name_tok.text(src));
            }
        }
    }
}

// --------------------------------------------------------------- C1 engine

/// Collect calendar payload evidence from one file: `register(…)` calls
/// naming an `EventKind::K` (encoded iff the argument list contains
/// `to_bits`) and payload reads (`.payload`), attributed to a kind
/// either through an enclosing `EventKind::K =>` match arm or — when the
/// enclosing fn registers exactly one kind — through that fn.
fn c1_collect(
    pf: &ParsedFile,
    rel: &str,
    tranges: &[(usize, usize)],
    cal: &mut CalendarUsage,
) {
    let src = pf.src.as_str();
    let sig_tok = |k: usize| pf.sig.get(k).map(|&ti| &pf.tokens[ti]);

    // Function body ranges (token-index spans), for the single-kind
    // attribution fallback.
    let mut fn_bodies: Vec<(usize, usize)> = Vec::new();
    for (k, &ti) in pf.sig.iter().enumerate() {
        if !pf.tokens[ti].is_ident(src, "fn") {
            continue;
        }
        for j in k + 1..pf.sig.len() {
            let tj = pf.sig[j];
            if pf.tokens[tj].is_punct(src, '{') {
                if let Some(&close) = pf.pairs.get(&tj) {
                    fn_bodies.push((tj, close));
                }
                break;
            }
            if pf.tokens[tj].is_punct(src, ';') {
                break; // trait method signature without a body
            }
        }
    }
    let fn_of = |ti: usize| -> Option<usize> {
        fn_bodies
            .iter()
            .enumerate()
            .filter(|(_, &(a, b))| a < ti && ti < b)
            .max_by_key(|(_, &(a, _))| a)
            .map(|(i, _)| i)
    };

    // Register sites.
    let mut fn_kinds: BTreeMap<usize, BTreeSet<String>> = BTreeMap::new();
    for (k, &ti) in pf.sig.iter().enumerate() {
        let t = &pf.tokens[ti];
        if !t.is_ident(src, "register") || in_ranges(tranges, t.line) {
            continue;
        }
        if k > 0 && sig_tok(k - 1).is_some_and(|p| p.is_ident(src, "fn")) {
            continue; // the declaration of a register method
        }
        if !sig_tok(k + 1).is_some_and(|t| t.is_punct(src, '(')) {
            continue;
        }
        let open_ti = pf.sig[k + 1];
        let close_ti = match pf.pairs.get(&open_ti) {
            Some(&c) => c,
            None => pf.tokens.len(),
        };
        let group: Vec<&Token> = pf
            .sig
            .iter()
            .skip(k + 2)
            .take_while(|&&tj| tj < close_ti)
            .map(|&tj| &pf.tokens[tj])
            .collect();
        let encoded = group
            .iter()
            .any(|t| t.kind == TokKind::Ident && t.text(src) == "to_bits");
        for (j, g) in group.iter().enumerate() {
            if g.is_ident(src, "EventKind")
                && group.get(j + 1).is_some_and(|t| t.is_punct(src, ':'))
                && group.get(j + 2).is_some_and(|t| t.is_punct(src, ':'))
            {
                if let Some(kind_tok) =
                    group.get(j + 3).filter(|t| t.kind == TokKind::Ident)
                {
                    let kind = kind_tok.text(src).to_string();
                    cal.registers.entry(kind.clone()).or_default().push((
                        encoded,
                        rel.to_string(),
                        t.line + 1,
                    ));
                    if let Some(f) = fn_of(ti) {
                        fn_kinds.entry(f).or_default().insert(kind);
                    }
                }
            }
        }
    }

    // Match-arm decode sites: `EventKind::K => <body>` where the body
    // reads `.payload`.
    let mut claimed: BTreeSet<usize> = BTreeSet::new(); // token indices of claimed `payload`
    for (k, &ti) in pf.sig.iter().enumerate() {
        let t = &pf.tokens[ti];
        if !t.is_ident(src, "EventKind")
            || !sig_tok(k + 1).is_some_and(|t| t.is_punct(src, ':'))
            || !sig_tok(k + 2).is_some_and(|t| t.is_punct(src, ':'))
        {
            continue;
        }
        let Some(kind_tok) = sig_tok(k + 3).filter(|t| t.kind == TokKind::Ident) else {
            continue;
        };
        // Arrow: `=>` — possibly after a pattern binding like `(id)`.
        let mut arrow = None;
        for j in k + 4..(k + 12).min(pf.sig.len()) {
            let a = &pf.tokens[pf.sig[j]];
            if a.is_punct(src, '=')
                && sig_tok(j + 1).is_some_and(|b| b.is_punct(src, '>') && a.hi == b.lo)
            {
                arrow = Some(j);
                break;
            }
            if a.is_punct(src, ',') || a.is_punct(src, '{') || a.is_punct(src, ';') {
                break;
            }
        }
        let Some(arrow) = arrow else { continue };
        if in_ranges(tranges, t.line) {
            continue;
        }
        // Arm body: a brace block, or tokens up to the top-level comma.
        let body_start = arrow + 2;
        let mut body: Vec<usize> = Vec::new(); // sig positions
        if sig_tok(body_start).is_some_and(|t| t.is_punct(src, '{')) {
            let open_ti = pf.sig[body_start];
            let close_ti = pf.pairs.get(&open_ti).copied().unwrap_or(pf.tokens.len());
            for j in body_start..pf.sig.len() {
                if pf.sig[j] > close_ti {
                    break;
                }
                body.push(j);
            }
        } else {
            let mut depth = 0usize;
            for j in body_start..pf.sig.len() {
                let b = &pf.tokens[pf.sig[j]];
                if b.kind == TokKind::Punct {
                    match b.text(src).chars().next() {
                        Some('(' | '[' | '{') => depth += 1,
                        Some(')' | ']' | '}') => {
                            if depth == 0 {
                                break;
                            }
                            depth -= 1;
                        }
                        Some(',') if depth == 0 => break,
                        _ => {}
                    }
                }
                body.push(j);
            }
        }
        let mut reads_payload = false;
        for (bi, &j) in body.iter().enumerate() {
            if pf.tokens[pf.sig[j]].is_punct(src, '.')
                && body
                    .get(bi + 1)
                    .is_some_and(|&j2| pf.tokens[pf.sig[j2]].is_ident(src, "payload"))
            {
                reads_payload = true;
                claimed.insert(pf.sig[body[bi + 1]]);
            }
        }
        if reads_payload {
            let decoded = body
                .iter()
                .any(|&j| pf.tokens[pf.sig[j]].is_ident(src, "from_bits"));
            cal.decodes
                .entry(kind_tok.text(src).to_string())
                .or_default()
                .push((decoded, rel.to_string(), kind_tok.line + 1));
        }
    }

    // Fallback decode sites: `.payload` outside any claimed arm, in a fn
    // that registers exactly one kind.
    for (k, &ti) in pf.sig.iter().enumerate() {
        let t = &pf.tokens[ti];
        if !t.is_punct(src, '.')
            || !sig_tok(k + 1).is_some_and(|t| t.is_ident(src, "payload"))
        {
            continue;
        }
        let pay_ti = pf.sig[k + 1];
        if claimed.contains(&pay_ti) || in_ranges(tranges, t.line) {
            continue;
        }
        let Some(f) = fn_of(ti) else { continue };
        let Some(kinds) = fn_kinds.get(&f) else { continue };
        if kinds.len() != 1 {
            continue;
        }
        let kind = kinds.iter().next().expect("len checked").clone();
        // Statement extent: between the nearest boundaries around `k`.
        let boundary = |t: &Token| {
            t.kind == TokKind::Punct && matches!(t.text(src).chars().next(), Some(';' | '{' | '}'))
        };
        let mut lo = k;
        while lo > 0 && !boundary(&pf.tokens[pf.sig[lo - 1]]) {
            lo -= 1;
        }
        let mut hi = k;
        while hi + 1 < pf.sig.len() && !boundary(&pf.tokens[pf.sig[hi + 1]]) {
            hi += 1;
        }
        let decoded = (lo..=hi).any(|j| pf.tokens[pf.sig[j]].is_ident(src, "from_bits"));
        cal.decodes.entry(kind).or_default().push((
            decoded,
            rel.to_string(),
            pf.tokens[pay_ti].line + 1,
        ));
    }
}

// --------------------------------------------------------------- D1 helpers

/// Names bound to `HashMap`/`HashSet` on this (stripped) line, via either
/// a struct-field/param type (`name: HashMap<...>`) or a constructor
/// binding (`name = HashMap::new()`).
fn hash_decl_names(line: &str) -> Vec<String> {
    let mut names = Vec::new();
    for key in ["HashMap<", "HashSet<", "HashMap::", "HashSet::"] {
        let mut from = 0;
        while let Some(rel_pos) = line[from..].find(key) {
            let pos = from + rel_pos;
            from = pos + key.len();
            let before = strip_suffix_path(&line[..pos]);
            let name = if key.ends_with('<') {
                // `name: HashMap<` (field or typed local).
                ident_before_char(before, ':')
            } else {
                // `name = HashMap::new()` — reject `==`, `<=`, etc.
                ident_before_char(before, '=').filter(|_| {
                    let t = before.trim_end();
                    !t.ends_with("==") && !t.ends_with("<=") && !t.ends_with(">=")
                })
            };
            if let Some(name) = name {
                if !names.contains(&name) {
                    names.push(name);
                }
            }
        }
    }
    names
}

/// Drop a trailing `std::collections::`-style path prefix so the
/// character before the type name can be inspected.
fn strip_suffix_path(s: &str) -> &str {
    let mut out = s;
    for p in ["std::collections::", "collections::", "std::"] {
        if let Some(t) = out.strip_suffix(p) {
            out = t;
        }
    }
    out
}

/// If `s` ends (modulo spaces) with `<sep>` preceded by an identifier,
/// return that identifier. `name: ` → Some("name") for sep ':'. Rejects
/// the path separator `::` when sep is ':'.
fn ident_before_char(s: &str, sep: char) -> Option<String> {
    let t = s.trim_end();
    let t = t.strip_suffix(sep)?;
    if sep == ':' && t.ends_with(':') {
        return None;
    }
    let t = t.trim_end();
    let ident: String = t
        .chars()
        .rev()
        .take_while(|c| c.is_alphanumeric() || *c == '_')
        .collect::<Vec<_>>()
        .into_iter()
        .rev()
        .collect();
    if ident.is_empty() || ident.chars().next().is_some_and(|c| c.is_ascii_digit()) {
        None
    } else {
        Some(ident)
    }
}

/// Does this line iterate the hash collection `name`? Matches
/// `name.iter()`-style calls (only bare `name` or `self.name` — a
/// `view.name` refers to some other binding) and `for … in …name` loops.
fn iterates_hash(line: &str, name: &str) -> bool {
    // Method form: name.<iter-method>(
    let mut from = 0;
    while let Some(rel_pos) = line[from..].find(name) {
        let pos = from + rel_pos;
        from = pos + name.len();
        if !receiver_boundary_ok(line, pos) {
            continue;
        }
        let after = &line[pos + name.len()..];
        let Some(rest) = after.strip_prefix('.') else {
            continue;
        };
        let method: String = rest
            .chars()
            .take_while(|c| c.is_alphanumeric() || *c == '_')
            .collect();
        if ITER_METHODS.contains(&method.as_str())
            && rest[method.len()..].trim_start().starts_with('(')
        {
            return true;
        }
    }
    // Loop form: for … in [&][mut ][self.]name<non-ident>
    if let Some(for_pos) = find_token(line, "for ") {
        if let Some(in_rel) = line[for_pos..].find(" in ") {
            let mut rhs = line[for_pos + in_rel + 4..].trim_start();
            rhs = rhs.strip_prefix('&').unwrap_or(rhs);
            rhs = rhs.strip_prefix("mut ").unwrap_or(rhs).trim_start();
            rhs = rhs.strip_prefix("self.").unwrap_or(rhs);
            if let Some(after) = rhs.strip_prefix(name) {
                let next = after.chars().next();
                if !matches!(next, Some(c) if c.is_alphanumeric() || c == '_' || c == '.') {
                    return true;
                }
            }
        }
    }
    false
}

/// The characters before a receiver occurrence must be either nothing,
/// a non-identifier character, or exactly `self.` — so `other.name.iter()`
/// never matches a field named `name`.
fn receiver_boundary_ok(line: &str, pos: usize) -> bool {
    let before = &line[..pos];
    match before.chars().next_back() {
        None => true,
        Some('.') => {
            let t = &before[..before.len() - 1];
            t.ends_with("self")
                && !t[..t.len() - 4]
                    .chars()
                    .next_back()
                    .is_some_and(|c| c.is_alphanumeric() || c == '_' || c == '.')
        }
        Some(c) => !(c.is_alphanumeric() || c == '_'),
    }
}

/// Find `token` at an identifier boundary (the char before must not be
/// part of an identifier).
fn find_token(line: &str, token: &str) -> Option<usize> {
    let mut from = 0;
    while let Some(rel_pos) = line[from..].find(token) {
        let pos = from + rel_pos;
        let ok = line[..pos]
            .chars()
            .next_back()
            .map(|c| !(c.is_alphanumeric() || c == '_'))
            .unwrap_or(true);
        if ok {
            return Some(pos);
        }
        from = pos + token.len();
    }
    None
}

// --------------------------------------------------------------- X1 helpers

/// The (inclusive, 0-based) line range of `fn declare_base_families`, if
/// this file defines it, via brace-depth tracking.
fn declare_fn_range(code: &[String]) -> Option<(usize, usize)> {
    let start = code
        .iter()
        .position(|l| l.contains("fn declare_base_families"))?;
    let mut depth: i64 = 0;
    let mut opened = false;
    for (li, line) in code.iter().enumerate().skip(start) {
        for c in line.chars() {
            if c == '{' {
                depth += 1;
                opened = true;
            } else if c == '}' {
                depth -= 1;
            }
        }
        if opened && depth == 0 {
            return Some((start, li));
        }
    }
    Some((start, code.len().saturating_sub(1)))
}

/// Is there an emit-call token on the literal's line before its column,
/// or on one of up to two continuation lines above it (rustfmt wraps
/// `registry.observe(` and the family name onto separate lines)?
fn emit_token_nearby(code: &[String], line: usize, col: usize) -> bool {
    for back in 0..3usize {
        let Some(li) = line.checked_sub(back) else {
            break;
        };
        let Some(lcode) = code.get(li) else {
            continue;
        };
        let limit = if back == 0 { col } else { lcode.len() };
        if EMIT_TOKENS
            .iter()
            .any(|t| lcode.find(t).is_some_and(|p| p <= limit))
        {
            return true;
        }
        // A non-continuation line above ends the lookback: the literal
        // belongs to whatever expression starts there.
        if back > 0 {
            let trimmed = lcode.trim_end();
            if !trimmed.is_empty() && !trimmed.ends_with('(') && !trimmed.ends_with(',') {
                break;
            }
        }
    }
    false
}

#[cfg(test)]
mod tests {
    use super::*;

    fn scan(rel: &str, text: &str) -> Vec<Finding> {
        let mut usage = CrossUsage::default();
        scan_source(rel, text, &mut usage).findings
    }

    #[test]
    fn d1_flags_iteration_not_lookup() {
        let src = "struct S { m: HashMap<u64, u32> }\nimpl S {\n fn f(&self) {\n  \
                   for (k, v) in &self.m {}\n  let _ = self.m.get(&1);\n } }";
        let f = scan("rust/src/x.rs", src);
        assert_eq!(f.iter().filter(|f| f.rule == "D1").count(), 1);
        assert_eq!(f[0].line, 4);
    }

    #[test]
    fn d1_respects_receiver_boundaries() {
        // `view.active` is not the declared `active` — no finding.
        let src = "struct S { active: HashSet<u64> }\nfn f(view: &View) { \
                   for id in view.active.iter() {} }";
        assert!(scan("rust/src/x.rs", src).is_empty());
        // But `self.active.iter()` and bare `active.iter()` are.
        let src2 = "struct S { active: HashSet<u64> }\nfn g(s: &S) { s.x(); }\n\
                    impl S { fn h(&self) { self.active.iter().count(); } }";
        assert_eq!(scan("rust/src/x.rs", src2).len(), 1);
    }

    #[test]
    fn d2_scoped_to_wall_domain() {
        let src = "fn f() { let t = Instant::now(); }";
        assert_eq!(scan("rust/src/coordinator/engine.rs", src).len(), 1);
        assert!(scan("rust/src/server/mod.rs", src).is_empty());
        assert!(scan("rust/src/util/bench.rs", src).is_empty());
    }

    #[test]
    fn d2_env_read_scoped_to_sim_paths() {
        let src = "pub fn trace_on() -> bool { std::env::var(\"ANDES_TRACE_CAP\").is_ok() }";
        let f = scan("rust/src/coordinator/fx.rs", src);
        assert_eq!(f.iter().map(|f| f.rule).collect::<Vec<_>>(), vec!["D2"]);
        assert!(f[0].message.contains("environment read"), "{}", f[0].message);
        // Outside the sim scope (util/, benches) the same read is fine.
        assert!(scan("rust/src/util/fx.rs", src).is_empty());
        // Test code inside a sim-scoped file is exempt.
        let test_src = "fn lib() {}\n#[cfg(test)]\nmod tests {\n \
                        fn t() { let _ = std::env::var(\"X\"); }\n}";
        assert!(scan("rust/src/coordinator/fx.rs", test_src).is_empty());
    }

    #[test]
    fn d3_catches_wrapped_unwrap() {
        let src = "xs.sort_by(|a, b| {\n a.partial_cmp(b)\n  .unwrap()\n});";
        let f = scan("rust/src/x.rs", src);
        assert_eq!(f.iter().filter(|f| f.rule == "D3").count(), 1);
        // total_cmp is the fix and must not fire.
        assert!(scan("rust/src/x.rs", "xs.sort_by(|a, b| a.total_cmp(b));").is_empty());
    }

    #[test]
    fn d5_and_d6_skip_cfg_test_blocks() {
        let src = "fn lib() {}\n#[cfg(test)]\nmod tests {\n fn t() { println!(\"x\"); \
                   None::<u8>.unwrap(); }\n}";
        assert!(scan("rust/src/coordinator/x.rs", src).is_empty());
    }

    #[test]
    fn d6_suppression_with_reason() {
        let src = "fn f(v: &[u8]) {\n // lint:allow(D6, slice checked non-empty above)\n \
                   v.first().unwrap();\n}";
        let mut usage = CrossUsage::default();
        let r = scan_source("rust/src/coordinator/x.rs", src, &mut usage);
        assert!(r.findings.is_empty());
        assert_eq!(r.suppressed, 1);
    }

    #[test]
    fn d7_tracks_taint_across_lines_into_a_sink() {
        // D2 is out of the way (wall domain) — only the flow fires.
        let src = "fn f(cal: &mut EventCalendar) {\n\
                   \x20let t0 = std::time::Instant::now();\n\
                   \x20let dt = t0.elapsed();\n\
                   \x20cal.register(dt.as_secs_f64(), EventKind::Arrival, 0);\n}";
        let f = scan("rust/src/server/x.rs", src);
        assert_eq!(f.iter().map(|f| f.rule).collect::<Vec<_>>(), vec!["D7"]);
        assert_eq!(f[0].line, 4);
        assert!(f[0].message.contains("`dt`"), "{}", f[0].message);
    }

    #[test]
    fn d7_fires_on_sim_arithmetic_mix() {
        let src = "fn f(sim_now: f64, t0: std::time::Instant) -> f64 {\n\
                   \x20let due = sim_now + t0.elapsed().as_secs_f64();\n\
                   \x20due\n}";
        let f = scan("rust/src/server/x.rs", src);
        assert_eq!(f.iter().map(|f| f.rule).collect::<Vec<_>>(), vec!["D7"]);
        assert_eq!(f[0].line, 2);
    }

    #[test]
    fn d7_stays_silent_on_wall_only_profiling() {
        // The engine's own profiling idiom: elapsed feeds a wall-side
        // accumulator, no sim identifier in the statement.
        let src = "fn f(m: &mut M) {\n\
                   \x20let t0 = std::time::Instant::now();\n\
                   \x20m.sched_seconds += t0.elapsed().as_secs_f64();\n}";
        let f = scan("rust/src/server/x.rs", src);
        assert!(f.is_empty(), "{f:?}");
    }

    #[test]
    fn d7_scopes_taint_to_blocks() {
        // Taint dies with its block; the same name outside is clean.
        let src = "fn f(cal: &mut C, sim_now: f64) {\n\
                   \x20{ let t = std::time::Instant::now(); drop(t); }\n\
                   \x20let t = sim_now;\n\
                   \x20cal.register(t, EventKind::Arrival, 0);\n}";
        let f = scan("rust/src/server/x.rs", src);
        assert!(f.is_empty(), "{f:?}");
    }

    #[test]
    fn c2_flags_direct_clock_mutation() {
        let src = "impl S {\n fn step(&mut self, dt: f64) {\n  self.now += dt;\n }\n\
                   \x20fn reset(&mut self) {\n  self.now = 0.0;\n }\n}";
        let f = scan("rust/src/gateway/x.rs", src);
        assert_eq!(f.iter().map(|f| f.rule).collect::<Vec<_>>(), vec!["C2", "C2"]);
        // The same text under coordinator/ is sanctioned.
        assert!(scan("rust/src/coordinator/x.rs", src).is_empty());
    }

    #[test]
    fn c2_ignores_bindings_comparisons_and_fields() {
        let src = "struct S { now: f64 }\nfn f(s: &S) -> bool {\n\
                   \x20let now = s.now;\n let mut now2 = now;\n now2 = 1.0;\n\
                   \x20now == 0.0 || s.now >= 2.0\n}";
        let f = scan("rust/src/gateway/x.rs", src);
        assert!(f.is_empty(), "{f:?}");
    }

    #[test]
    fn c1_mismatch_reconciles_across_register_and_pop() {
        let mut usage = CrossUsage::default();
        let reg = "fn schedule(cal: &mut C, q: f64) {\n\
                   \x20cal.register(1.0, EventKind::DeliveryAck, q.to_bits());\n}";
        scan_source("rust/src/delivery/a.rs", reg, &mut usage);
        let pop = "fn drain(cal: &mut C, out: &mut Vec<f64>) {\n\
                   \x20while let Some(w) = cal.pop() {\n\
                   \x20 match w.kind {\n\
                   \x20  EventKind::DeliveryAck => out.push(w.payload as f64),\n\
                   \x20  _ => {}\n\
                   \x20 }\n\x20}\n}";
        scan_source("rust/src/delivery/b.rs", pop, &mut usage);
        let x = cross_check(&usage);
        assert_eq!(x.iter().map(|f| f.rule).collect::<Vec<_>>(), vec!["C1"]);
        assert_eq!(x[0].file, "rust/src/delivery/b.rs");
        assert!(x[0].message.contains("from_bits"), "{}", x[0].message);
    }

    #[test]
    fn c1_single_kind_fn_attribution_without_match() {
        // The delivery idiom: a while-let pop loop with no match — the
        // enclosing fn registers exactly one kind, so the read is
        // attributed to it.
        let mut usage = CrossUsage::default();
        let src = "fn pump(cal: &mut C, v: f64) {\n\
                   \x20cal.register(2.0, EventKind::DeliveryAck, v.to_bits());\n\
                   \x20while let Some(w) = cal.pop() {\n\
                   \x20 observe(f64::from_bits(w.payload));\n\x20}\n}";
        scan_source("rust/src/delivery/c.rs", src, &mut usage);
        assert!(cross_check(&usage).is_empty());
        let sites = &usage.calendar.decodes["DeliveryAck"];
        assert_eq!(sites.len(), 1);
        assert!(sites[0].0, "decode should be recognized as from_bits");
    }

    #[test]
    fn w1_reports_stale_waivers() {
        let src = "// lint:allow(D2, the wall read moved away)\nfn f() {}\n";
        let f = scan("rust/src/coordinator/x.rs", src);
        assert_eq!(f.iter().map(|f| f.rule).collect::<Vec<_>>(), vec!["W1"]);
        assert_eq!(f[0].line, 1);
        assert!(f[0].message.contains("D2"), "{}", f[0].message);
    }

    #[test]
    fn x1_reconciles_declared_and_emitted() {
        let mut usage = CrossUsage::default();
        let decl = "fn declare_base_families(r: &mut Registry) {\n \
                    r.declare_counter(\"andes_a_total\");\n \
                    r.declare_gauge(\"andes_ghost\");\n}";
        scan_source("rust/src/telemetry/mod.rs", decl, &mut usage);
        let emit = "fn f(m: &Metrics) {\n m.inc(\"andes_a_total\", 1);\n \
                    m.inc(\"andes_rogue_total\", 1);\n}";
        scan_source("rust/src/gateway/mod.rs", emit, &mut usage);
        let x = cross_check(&usage);
        let msgs: Vec<&str> = x.iter().map(|f| f.excerpt.as_str()).collect();
        assert_eq!(msgs, vec!["andes_rogue_total", "andes_ghost"]);
    }

    #[test]
    fn strings_and_comments_never_fire() {
        let src = "// partial_cmp(a).unwrap() in a comment\n\
                   let s = \"Instant::now() thread_rng println!\";\n\
                   /* SystemTime */ fn f() {}";
        assert!(scan("rust/src/coordinator/x.rs", src).is_empty());
    }
}
