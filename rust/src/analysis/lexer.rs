//! Comment/string strip pass for the determinism lint.
//!
//! Rule matching must never fire on a pattern that only occurs inside a
//! doc comment or a string literal (`DESIGN.md` §13), so every file is
//! first run through [`strip_source`]: a line-preserving scanner that
//! blanks comments and literal contents with spaces. Columns survive
//! (each stripped span is replaced by exactly as many characters as it
//! covered), which is what lets the rules report accurate positions and
//! the X1 cross-check associate string literals with the call tokens in
//! front of them. The strip pass is property-tested to never change the
//! line count (`rust/tests/lint.rs`).
//!
//! ```
//! let s = andes::analysis::lexer::strip_source("let x = 1; // Instant::now()\n");
//! assert!(!s.code[0].contains("Instant"));
//! assert!(s.comments[0].contains("Instant::now()"));
//! ```

/// A string literal found during the strip pass, with its contents and
/// the (0-based) line/column where it opened.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct StrLit {
    pub line: usize,
    pub col: usize,
    pub content: String,
}

/// Result of [`strip_source`]: `code` and `comments` always hold exactly
/// one entry per input line.
#[derive(Debug, Clone, Default)]
pub struct Stripped {
    /// Source with comments and string/char-literal contents blanked.
    pub code: Vec<String>,
    /// The comment text found on each line (empty when none).
    pub comments: Vec<String>,
    /// Every string literal, in source order.
    pub strings: Vec<StrLit>,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum State {
    Normal,
    /// Inside `/* */`, tracking nesting depth.
    Block(u32),
    /// Inside a `"…"` (or `b"…"`) literal.
    Str,
    /// Inside a raw string, remembering the `#` count of the opener.
    RawStr(usize),
}

/// Strip comments and literal contents from Rust source (see module
/// docs). Total over arbitrary input: unterminated constructs simply
/// run to end-of-file without panicking.
pub fn strip_source(text: &str) -> Stripped {
    let mut out = Stripped::default();
    let mut state = State::Normal;
    let mut lit = String::new();
    let mut lit_start = (0usize, 0usize);
    for (li, raw_line) in text.split('\n').enumerate() {
        let line: Vec<char> = raw_line.chars().collect();
        let n = line.len();
        let mut code = String::with_capacity(n);
        let mut comment = String::new();
        let mut i = 0usize;
        while i < n {
            let c = line[i];
            match state {
                State::Block(depth) => {
                    if starts(&line, i, "/*") {
                        state = State::Block(depth + 1);
                        comment.push_str("/*");
                        code.push_str("  ");
                        i += 2;
                    } else if starts(&line, i, "*/") {
                        comment.push_str("*/");
                        code.push_str("  ");
                        i += 2;
                        state = if depth <= 1 {
                            State::Normal
                        } else {
                            State::Block(depth - 1)
                        };
                    } else {
                        comment.push(c);
                        code.push(' ');
                        i += 1;
                    }
                }
                State::Str => {
                    if c == '\\' && i + 1 < n {
                        lit.push(c);
                        lit.push(line[i + 1]);
                        code.push_str("  ");
                        i += 2;
                    } else if c == '"' {
                        out.strings.push(StrLit {
                            line: lit_start.0,
                            col: lit_start.1,
                            content: std::mem::take(&mut lit),
                        });
                        code.push(' ');
                        i += 1;
                        state = State::Normal;
                    } else {
                        lit.push(c);
                        code.push(' ');
                        i += 1;
                    }
                }
                State::RawStr(hashes) => {
                    if c == '"' && count_hashes(&line, i + 1) >= hashes {
                        out.strings.push(StrLit {
                            line: lit_start.0,
                            col: lit_start.1,
                            content: std::mem::take(&mut lit),
                        });
                        for _ in 0..hashes + 1 {
                            code.push(' ');
                        }
                        i += hashes + 1;
                        state = State::Normal;
                    } else {
                        lit.push(c);
                        code.push(' ');
                        i += 1;
                    }
                }
                State::Normal => {
                    if starts(&line, i, "//") {
                        for &cc in &line[i..] {
                            comment.push(cc);
                            code.push(' ');
                        }
                        i = n;
                    } else if starts(&line, i, "/*") {
                        state = State::Block(1);
                        comment.push_str("/*");
                        code.push_str("  ");
                        i += 2;
                    } else if c == '"' {
                        state = State::Str;
                        lit_start = (li, i);
                        code.push(' ');
                        i += 1;
                    } else if let Some(hashes) = raw_string_start(&line, i) {
                        // `r"`, `r#"`, `br"`, … — consume prefix + hashes
                        // + the opening quote.
                        let prefix = if c == 'b' { 2 } else { 1 };
                        state = State::RawStr(hashes);
                        lit_start = (li, i);
                        for _ in 0..prefix + hashes + 1 {
                            code.push(' ');
                        }
                        i += prefix + hashes + 1;
                    } else if !ident_before(&line, i) && starts(&line, i, "b\"") {
                        state = State::Str;
                        lit_start = (li, i);
                        code.push_str("b ");
                        i += 2;
                    } else if c == '\'' {
                        match char_literal_len(&line, i) {
                            Some(len) => {
                                code.push('\'');
                                for _ in 0..len.saturating_sub(2) {
                                    code.push(' ');
                                }
                                code.push('\'');
                                i += len;
                            }
                            None => {
                                // Lifetime marker — plain code.
                                code.push(c);
                                i += 1;
                            }
                        }
                    } else {
                        code.push(c);
                        i += 1;
                    }
                }
            }
        }
        // A literal that continues past the line keeps its newline.
        if matches!(state, State::Str | State::RawStr(_)) {
            lit.push('\n');
        }
        out.code.push(code);
        out.comments.push(comment);
    }
    out
}

fn starts(line: &[char], i: usize, pat: &str) -> bool {
    let pat: Vec<char> = pat.chars().collect();
    i + pat.len() <= line.len() && line[i..i + pat.len()] == pat[..]
}

fn count_hashes(line: &[char], mut i: usize) -> usize {
    let mut h = 0;
    while i < line.len() && line[i] == '#' {
        h += 1;
        i += 1;
    }
    h
}

fn ident_before(line: &[char], i: usize) -> bool {
    i > 0 && (line[i - 1].is_alphanumeric() || line[i - 1] == '_')
}

/// If `line[i..]` opens a raw string (`r"`, `r#…#"`, `br"`, `br#…#"`),
/// return its `#` count; `None` otherwise. Identifiers ending in `r`
/// (e.g. `var"` cannot appear, but `attr` before `"` could in macros)
/// are rejected via the preceding-character check.
fn raw_string_start(line: &[char], i: usize) -> Option<usize> {
    if ident_before(line, i) {
        return None;
    }
    let rest = &line[i..];
    let after_prefix = if rest.first() == Some(&'r') {
        1
    } else if rest.first() == Some(&'b') && rest.get(1) == Some(&'r') {
        2
    } else {
        return None;
    };
    let hashes = count_hashes(line, i + after_prefix);
    if line.get(i + after_prefix + hashes) == Some(&'"') {
        Some(hashes)
    } else {
        None
    }
}

/// Total length of the char literal starting at `line[i] == '\''`
/// (`'x'`, `'\n'`, `'\u{1F600}'`), or `None` when this is a lifetime.
fn char_literal_len(line: &[char], i: usize) -> Option<usize> {
    let n = line.len();
    if i + 1 >= n {
        return None;
    }
    if line[i + 1] == '\\' {
        if i + 2 < n && line[i + 2] == 'u' {
            // '\u{…}' — find the closing quote.
            for j in i + 3..n {
                if line[j] == '\'' {
                    return Some(j - i + 1);
                }
            }
            return None;
        }
        // One escaped character then the closing quote.
        if i + 3 < n && line[i + 3] == '\'' {
            return Some(4);
        }
        return None;
    }
    // 'x' — exactly one character, then the closing quote.
    if i + 2 < n && line[i + 2] == '\'' && line[i + 1] != '\'' {
        return Some(3);
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn line_comments_are_stripped_and_captured() {
        let s = strip_source("let a = 1; // trailing note\n/// doc with partial_cmp\nlet b = 2;");
        assert_eq!(s.code.len(), 3);
        assert!(!s.code[0].contains("trailing"));
        assert!(s.comments[0].contains("trailing note"));
        assert!(!s.code[1].contains("partial_cmp"));
        assert!(s.code[2].contains("let b"));
    }

    #[test]
    fn block_comments_nest_and_span_lines() {
        let s = strip_source("a /* one /* two */ still */ b\nc /* open\nmid\nclose */ d");
        assert!(s.code[0].contains('a') && s.code[0].contains('b'));
        assert!(!s.code[0].contains("still"));
        assert!(s.code[1].contains('c') && !s.code[1].contains("open"));
        assert_eq!(s.code[2].trim(), "");
        assert!(s.code[3].contains('d'));
    }

    #[test]
    fn strings_are_blanked_but_recorded() {
        let s = strip_source(r#"emit("unwrap() in a string", "two \"quoted\"");"#);
        assert!(!s.code[0].contains("unwrap"));
        assert_eq!(s.strings.len(), 2);
        assert_eq!(s.strings[0].content, "unwrap() in a string");
        assert!(s.strings[1].content.contains("quoted"));
        // Columns survive blanking: the call and punctuation remain.
        assert!(s.code[0].starts_with("emit("));
    }

    #[test]
    fn raw_strings_and_byte_strings() {
        let s = strip_source("let a = r#\"thread_rng()\"#; let b = b\"from_entropy\";");
        assert!(!s.code[0].contains("thread_rng"));
        assert!(!s.code[0].contains("from_entropy"));
        assert_eq!(s.strings[0].content, "thread_rng()");
        assert_eq!(s.strings[1].content, "from_entropy");
    }

    #[test]
    fn char_literals_vs_lifetimes() {
        let s = strip_source("let q = '\"'; fn f<'a>(x: &'a str) -> char { '\\n' }");
        // The quote char literal must not open a string.
        assert!(s.strings.is_empty());
        assert!(s.code[0].contains("fn f<'a>"));
    }

    #[test]
    fn multiline_string_preserves_line_count() {
        let text = "let s = \"line one\nline two\";\nafter();";
        let s = strip_source(text);
        assert_eq!(s.code.len(), 3);
        assert_eq!(s.strings.len(), 1);
        assert_eq!(s.strings[0].content, "line one\nline two");
        assert!(s.code[2].contains("after"));
    }

    #[test]
    fn unterminated_constructs_do_not_panic() {
        for text in ["\"open", "/* open", "r#\"open", "let a = 'x"] {
            let s = strip_source(text);
            assert_eq!(s.code.len(), 1, "{text:?}");
        }
    }
}
