//! Human and JSON rendering of a lint run.

use crate::util::json::{self, Json};

use super::rules::{Finding, RULE_TABLE};
use super::LintOutcome;

/// clippy/rustc-style one-line-per-finding report with a summary tail.
pub fn render_human(outcome: &LintOutcome) -> String {
    let mut out = String::new();
    for f in &outcome.findings {
        out.push_str(&format!("{}:{}: [{}] {}\n", f.file, f.line, f.rule, f.message));
        if !f.excerpt.is_empty() {
            out.push_str(&format!("    {}\n", f.excerpt));
        }
    }
    if !outcome.findings.is_empty() {
        out.push('\n');
    }
    let counts = rule_counts(&outcome.findings);
    if !counts.is_empty() {
        let parts: Vec<String> = counts.iter().map(|(r, n)| format!("{r}={n}")).collect();
        out.push_str(&format!("by rule: {}\n", parts.join(" ")));
    }
    out.push_str(&format!(
        "lint: {} finding(s) in {} file(s); {} suppressed, {} baselined\n",
        outcome.findings.len(),
        outcome.files_scanned,
        outcome.suppressed,
        outcome.baselined
    ));
    out.push_str(&format!(
        "metric families: {} declared, {} emitted\n",
        outcome.declared, outcome.emitted
    ));
    out
}

/// Machine-readable document for `andes lint --json`.
pub fn render_json(outcome: &LintOutcome) -> String {
    let findings: Vec<Json> = outcome
        .findings
        .iter()
        .map(|f| {
            Json::obj(vec![
                ("rule", Json::from(f.rule)),
                ("file", Json::from(f.file.as_str())),
                ("line", Json::from(f.line)),
                ("excerpt", Json::from(f.excerpt.as_str())),
                ("message", Json::from(f.message.as_str())),
            ])
        })
        .collect();
    let counts: Vec<Json> = rule_counts(&outcome.findings)
        .into_iter()
        .map(|(r, n)| Json::obj(vec![("rule", Json::from(r)), ("count", Json::from(n))]))
        .collect();
    let doc = Json::obj(vec![
        ("findings", Json::arr(findings)),
        ("by_rule", Json::arr(counts)),
        ("files_scanned", Json::from(outcome.files_scanned)),
        ("suppressed", Json::from(outcome.suppressed)),
        ("baselined", Json::from(outcome.baselined)),
        ("declared_families", Json::from(outcome.declared)),
        ("emitted_families", Json::from(outcome.emitted)),
    ]);
    let mut s = json::pretty(&doc);
    s.push('\n');
    s
}

/// Per-rule finding counts in [`RULE_TABLE`] order, zero rows omitted.
fn rule_counts(findings: &[Finding]) -> Vec<(&'static str, usize)> {
    RULE_TABLE
        .iter()
        .map(|&(rule, _)| (rule, findings.iter().filter(|f| f.rule == rule).count()))
        .filter(|&(_, n)| n > 0)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn outcome() -> LintOutcome {
        LintOutcome {
            findings: vec![Finding {
                rule: "D3",
                file: "rust/src/x.rs".to_string(),
                line: 7,
                excerpt: "xs.sort_by(...)".to_string(),
                message: "use total_cmp".to_string(),
            }],
            files_scanned: 4,
            suppressed: 2,
            baselined: 1,
            declared: 21,
            emitted: 21,
        }
    }

    #[test]
    fn human_report_lists_findings_and_summary() {
        let text = render_human(&outcome());
        assert!(text.contains("rust/src/x.rs:7: [D3] use total_cmp"));
        assert!(text.contains("by rule: D3=1"));
        assert!(text.contains("1 finding(s) in 4 file(s); 2 suppressed, 1 baselined"));
        assert!(text.contains("21 declared, 21 emitted"));
    }

    #[test]
    fn json_report_parses_back() {
        let text = render_json(&outcome());
        let v = Json::parse(&text).expect("valid json");
        let fs = v.get("findings").as_arr().expect("findings array");
        assert_eq!(fs.len(), 1);
        assert_eq!(fs[0].get("rule").as_str(), Some("D3"));
        assert_eq!(fs[0].get("line").as_u64(), Some(7));
        assert_eq!(v.get("by_rule").as_arr().map(|a| a.len()), Some(1));
        assert_eq!(v.get("files_scanned").as_u64(), Some(4));
    }
}
