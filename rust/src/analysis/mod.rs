//! In-tree determinism lint (`andes lint`).
//!
//! A dependency-light static-analysis pass over the repository's own
//! Rust sources that enforces the determinism contract the simulation
//! relies on (DESIGN.md §13): no hash-order iteration feeding results
//! (D1), no wall-clock reads outside the wall domain (D2), no NaN-unsafe
//! float comparisons (D3), no unseeded randomness (D4), no stray prints
//! in library code (D5), no unwrap/expect in simulation paths without a
//! reasoned waiver (D6), no wall-clock value flowing into sim-time
//! arithmetic (D7), a consistent calendar payload encode/decode protocol
//! (C1), no sim clock mutation outside `coordinator/` (C2), no stale
//! `lint:allow` waivers (W1), a declared-vs-emitted cross-check of the
//! telemetry metric taxonomy (X1), and cross-artifact consistency between
//! the sources and their paired non-Rust artifacts (X2–X5).
//!
//! The pipeline is: [`parse`] lexes each file into a spanned token
//! stream with a brace/paren/bracket tree, [`rules`] runs the
//! determinism rules on the tokens (line-oriented rules use the
//! [`parse::to_stripped`] projection, which is byte-identical to the
//! legacy [`lexer`] strip pass — kept as the independent oracle the
//! parser is tested against), [`suppress`] applies inline
//! `// lint:allow(...)` waivers, [`artifacts`] reconciles the sources
//! against DESIGN.md / ROADMAP.md / CI / bench baselines / the fixture
//! corpus, [`baseline`] subtracts grandfathered findings, and [`report`]
//! renders the rest. Everything is deterministic by construction: files
//! are walked in sorted order and all intermediate maps are BTreeMaps,
//! so two runs on the same tree produce byte-identical reports.

pub mod artifacts;
pub mod baseline;
pub mod lexer;
pub mod parse;
pub mod report;
pub mod rules;
pub mod suppress;

use std::fs;
use std::path::{Path, PathBuf};

use artifacts::Artifacts;
use baseline::Baseline;
use rules::{CrossUsage, Finding};

/// Directories scanned relative to the repo root. Fixture corpora under
/// any `lint_fixtures/` directory are exercised by the lint's own tests
/// and are skipped here.
pub const SCAN_ROOTS: &[&str] = &["rust/src", "rust/tests", "benches", "examples"];

/// Knobs for one lint run.
#[derive(Debug, Default)]
pub struct LintOptions {
    /// Restrict the report to a single rule id (e.g. `D3`).
    pub rule: Option<String>,
    /// Grandfathered findings to subtract (`lint-baseline.json`).
    pub baseline: Baseline,
}

/// Aggregated result of a lint run; `findings` holds only new (non-
/// suppressed, non-baselined) findings, sorted by file, line, rule.
#[derive(Debug, Default)]
pub struct LintOutcome {
    pub findings: Vec<Finding>,
    pub files_scanned: usize,
    pub suppressed: usize,
    pub baselined: usize,
    /// Distinct metric families seen in `declare_base_families`.
    pub declared: usize,
    /// Distinct metric families seen at emit sites.
    pub emitted: usize,
}

/// Lint a repository checkout rooted at `root`, including the X2–X5
/// cross-artifact checks against the checkout's non-Rust artifacts.
pub fn lint_repo(root: &Path, opts: &LintOptions) -> Result<LintOutcome, String> {
    let files = collect_sources(root)?;
    let art = artifacts::load_artifacts(root);
    Ok(lint_sources_with(&files, &art, opts))
}

/// Gather `(repo-relative path, contents)` for every `.rs` file under
/// [`SCAN_ROOTS`], in sorted order for run-to-run determinism.
pub fn collect_sources(root: &Path) -> Result<Vec<(String, String)>, String> {
    let mut out = Vec::new();
    for sub in SCAN_ROOTS {
        let dir = root.join(sub);
        if dir.is_dir() {
            walk_dir(&dir, sub, &mut out)?;
        }
    }
    Ok(out)
}

/// Lint an in-memory file set with no cross-artifact context: X2–X5 are
/// skipped (their paired artifacts are absent). This is what fixture
/// tests use to exercise the determinism rules in isolation.
pub fn lint_sources(files: &[(String, String)], opts: &LintOptions) -> LintOutcome {
    lint_sources_with(files, &Artifacts::default(), opts)
}

/// Lint an in-memory file set against an explicit artifact set. Split
/// out from [`lint_repo`] so tests can scan synthetic trees, fixture
/// corpora, and deliberately desynced artifact copies without touching
/// the disk layout.
pub fn lint_sources_with(
    files: &[(String, String)],
    art: &Artifacts,
    opts: &LintOptions,
) -> LintOutcome {
    let mut usage = CrossUsage::default();
    let mut findings = Vec::new();
    let mut suppressed = 0usize;
    for (rel, text) in files {
        let scan = rules::scan_source(rel, text, &mut usage);
        suppressed += scan.suppressed;
        findings.extend(scan.findings);
    }
    findings.extend(rules::cross_check(&usage));
    findings.extend(artifacts::cross_artifact_check(files, art));
    findings.sort_by(|a, b| {
        let ka = (a.file.as_str(), a.line, a.rule);
        ka.cmp(&(b.file.as_str(), b.line, b.rule))
    });
    if let Some(rule) = &opts.rule {
        findings.retain(|f| f.rule == rule.as_str());
    }
    let (fresh, baselined) = opts.baseline.apply(findings);
    LintOutcome {
        findings: fresh,
        files_scanned: files.len(),
        suppressed,
        baselined,
        declared: usage.metrics.declared.len(),
        emitted: usage.metrics.emitted.len(),
    }
}

fn walk_dir(dir: &Path, rel: &str, out: &mut Vec<(String, String)>) -> Result<(), String> {
    let rd = fs::read_dir(dir).map_err(|e| format!("read dir {}: {e}", dir.display()))?;
    let mut entries: Vec<(String, PathBuf)> = Vec::new();
    for ent in rd {
        let ent = ent.map_err(|e| format!("read dir {}: {e}", dir.display()))?;
        let name = ent.file_name().to_string_lossy().into_owned();
        entries.push((name, ent.path()));
    }
    entries.sort();
    for (name, path) in entries {
        let child_rel = format!("{rel}/{name}");
        if path.is_dir() {
            // Fixture corpora are known-bad on purpose; the lint's own
            // tests feed them through lint_sources directly.
            if name == "lint_fixtures" {
                continue;
            }
            walk_dir(&path, &child_rel, out)?;
        } else if name.ends_with(".rs") {
            let text =
                fs::read_to_string(&path).map_err(|e| format!("read {}: {e}", path.display()))?;
            out.push((child_rel, text));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn src(rel: &str, text: &str) -> (String, String) {
        (rel.to_string(), text.to_string())
    }

    #[test]
    fn lint_sources_sorts_and_counts() {
        let files = vec![
            src("rust/src/b.rs", "fn f() { let t = Instant::now(); }"),
            src(
                "rust/src/a.rs",
                "fn g(v: Vec<f64>) { v.sort_by(|a, b| a.partial_cmp(b).unwrap()); }",
            ),
        ];
        let out = lint_sources(&files, &LintOptions::default());
        assert_eq!(out.files_scanned, 2);
        let rules: Vec<&str> = out.findings.iter().map(|f| f.rule).collect();
        assert_eq!(rules, vec!["D3", "D2"]);
        assert!(out.findings[0].file < out.findings[1].file);
    }

    #[test]
    fn rule_filter_narrows_report() {
        let files = vec![src(
            "rust/src/a.rs",
            "fn f() { let t = Instant::now(); let r = thread_rng(); }",
        )];
        let opts = LintOptions { rule: Some("D4".to_string()), ..Default::default() };
        let out = lint_sources(&files, &opts);
        assert_eq!(out.findings.len(), 1);
        assert_eq!(out.findings[0].rule, "D4");
    }

    #[test]
    fn baseline_absorbs_known_findings() {
        let files = vec![src("rust/src/a.rs", "fn f() { let t = Instant::now(); }")];
        let all = lint_sources(&files, &LintOptions::default());
        assert_eq!(all.findings.len(), 1);
        let opts = LintOptions {
            rule: None,
            baseline: Baseline::from_findings(&all.findings),
        };
        let out = lint_sources(&files, &opts);
        assert!(out.findings.is_empty());
        assert_eq!(out.baselined, 1);
    }
}
