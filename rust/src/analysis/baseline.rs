//! Grandfather baseline for lint findings.
//!
//! `lint-baseline.json` at the repo root records, per (rule, file), how
//! many findings existed when the gate was adopted. [`Baseline::apply`]
//! subtracts those from a fresh scan so `andes lint --deny` only fails
//! on *new* debt; `--update-baseline` re-blesses the current state. CI
//! additionally refuses any commit that grows the file's `total`, which
//! makes the baseline ratchet-only: counts can shrink as findings are
//! fixed, never grow. The tree currently carries an empty baseline —
//! every pre-existing finding was either fixed or suppressed inline
//! with a reason — so the file exists purely as the ratchet anchor.

use std::collections::BTreeMap;

use super::rules::Finding;
use crate::util::json::{self, Json};

/// Current on-disk format version.
const VERSION: u64 = 1;

/// Allowance counts keyed by (rule, file).
#[derive(Debug, Default, Clone, PartialEq, Eq)]
pub struct Baseline {
    entries: BTreeMap<(String, String), u64>,
}

impl Baseline {
    /// A baseline that allows nothing.
    pub fn empty() -> Baseline {
        Baseline::default()
    }

    /// Parse the JSON document produced by [`Baseline::to_json`].
    pub fn parse(text: &str) -> Result<Baseline, String> {
        let v = Json::parse(text).map_err(|e| format!("baseline: {e}"))?;
        let version = v.get("version").as_u64().unwrap_or(0);
        if version != VERSION {
            return Err(format!("baseline: unsupported version {version}"));
        }
        let mut entries = BTreeMap::new();
        let list = v.get("entries").as_arr().unwrap_or(&[]);
        for e in list {
            let rule = e.get("rule").as_str().unwrap_or("").to_string();
            let file = e.get("file").as_str().unwrap_or("").to_string();
            let count = e.get("count").as_u64().unwrap_or(0);
            if rule.is_empty() || file.is_empty() || count == 0 {
                return Err("baseline: entry missing rule/file/count".to_string());
            }
            *entries.entry((rule, file)).or_insert(0) += count;
        }
        Ok(Baseline { entries })
    }

    /// Bless the given findings as the new baseline.
    pub fn from_findings(findings: &[Finding]) -> Baseline {
        let mut entries: BTreeMap<(String, String), u64> = BTreeMap::new();
        for f in findings {
            *entries.entry((f.rule.to_string(), f.file.clone())).or_insert(0) += 1;
        }
        Baseline { entries }
    }

    /// Split findings into (new, grandfathered-count). Within each
    /// (rule, file) bucket the first `count` findings — scan order, i.e.
    /// ascending line — are absorbed by the baseline.
    pub fn apply(&self, findings: Vec<Finding>) -> (Vec<Finding>, usize) {
        let mut remaining = self.entries.clone();
        let mut fresh = Vec::new();
        let mut absorbed = 0usize;
        for f in findings {
            let key = (f.rule.to_string(), f.file.clone());
            match remaining.get_mut(&key) {
                Some(n) if *n > 0 => {
                    *n -= 1;
                    absorbed += 1;
                }
                _ => fresh.push(f),
            }
        }
        (fresh, absorbed)
    }

    /// Total allowance across all entries (the CI ratchet quantity).
    pub fn total(&self) -> u64 {
        self.entries.values().sum()
    }

    /// Compare this (committed) baseline against a freshly-blessed one.
    /// The ratchet invariant: no (rule, file) bucket may grow. Shrinking
    /// or disappearing buckets are the absorbed delta `--update-baseline`
    /// reports; any growing bucket makes the update refuse.
    pub fn ratchet(&self, fresh: &Baseline) -> RatchetReport {
        let mut rows = Vec::new();
        let mut grew = false;
        let keys: std::collections::BTreeSet<&(String, String)> =
            self.entries.keys().chain(fresh.entries.keys()).collect();
        for key in keys {
            let old = self.entries.get(key).copied().unwrap_or(0);
            let new = fresh.entries.get(key).copied().unwrap_or(0);
            if old == new {
                continue;
            }
            if new > old {
                grew = true;
            }
            rows.push((key.0.clone(), key.1.clone(), old, new));
        }
        RatchetReport { rows, grew }
    }

    /// Serialize; stable field order via util::json's BTreeMap objects.
    pub fn to_json(&self) -> Json {
        let entries: Vec<Json> = self
            .entries
            .iter()
            .map(|((rule, file), count)| {
                Json::obj(vec![
                    ("rule", Json::from(rule.as_str())),
                    ("file", Json::from(file.as_str())),
                    ("count", Json::from(*count)),
                ])
            })
            .collect();
        Json::obj(vec![
            ("version", Json::from(VERSION)),
            ("total", Json::from(self.total())),
            ("entries", Json::arr(entries)),
        ])
    }

    /// Pretty document for `lint-baseline.json`, newline-terminated.
    pub fn render(&self) -> String {
        let mut s = json::pretty(&self.to_json());
        s.push('\n');
        s
    }
}

/// Per-bucket delta between a committed and a fresh baseline, produced
/// by [`Baseline::ratchet`]. Rows are (rule, file, old count, new
/// count), only for buckets whose count changed, in key order.
#[derive(Debug, Default)]
pub struct RatchetReport {
    pub rows: Vec<(String, String, u64, u64)>,
    /// True iff any bucket grew — the update must be refused.
    pub grew: bool,
}

impl RatchetReport {
    /// Human rendering, one `rule file: old -> new` line per changed
    /// bucket; empty string when nothing changed.
    pub fn render(&self) -> String {
        let mut s = String::new();
        for (rule, file, old, new) in &self.rows {
            s.push_str(&format!("  {rule} {file}: {old} -> {new}\n"));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn finding(rule: &'static str, file: &str, line: usize) -> Finding {
        Finding {
            rule,
            file: file.to_string(),
            line,
            excerpt: String::new(),
            message: String::new(),
        }
    }

    #[test]
    fn roundtrip_and_apply() {
        let found = vec![
            finding("D6", "rust/src/a.rs", 3),
            finding("D6", "rust/src/a.rs", 9),
            finding("D2", "rust/src/b.rs", 1),
        ];
        let base = Baseline::from_findings(&found);
        assert_eq!(base.total(), 3);
        let reparsed = Baseline::parse(&base.render()).expect("roundtrip");
        assert_eq!(reparsed, base);

        // Same findings: all absorbed.
        let (fresh, absorbed) = reparsed.apply(found.clone());
        assert!(fresh.is_empty());
        assert_eq!(absorbed, 3);

        // One extra D6 in a.rs: exactly one surfaces (the last in scan
        // order), and the ratchet quantity is unchanged.
        let mut grown = found;
        grown.insert(2, finding("D6", "rust/src/a.rs", 40));
        let (fresh, absorbed) = reparsed.apply(grown);
        assert_eq!(fresh.len(), 1);
        assert_eq!(fresh[0].line, 40);
        assert_eq!(absorbed, 3);
    }

    #[test]
    fn empty_baseline_absorbs_nothing() {
        let (fresh, absorbed) = Baseline::empty().apply(vec![finding("D1", "x.rs", 1)]);
        assert_eq!(fresh.len(), 1);
        assert_eq!(absorbed, 0);
        assert_eq!(Baseline::empty().total(), 0);
    }

    #[test]
    fn ratchet_flags_growth_and_reports_shrinkage() {
        let committed = Baseline::from_findings(&[
            finding("D6", "rust/src/a.rs", 3),
            finding("D6", "rust/src/a.rs", 9),
            finding("D2", "rust/src/b.rs", 1),
        ]);

        // Shrink: one D6 fixed, D2 gone — absorbed delta, no growth.
        let fresh = Baseline::from_findings(&[finding("D6", "rust/src/a.rs", 3)]);
        let rep = committed.ratchet(&fresh);
        assert!(!rep.grew);
        assert_eq!(
            rep.rows,
            vec![
                ("D2".to_string(), "rust/src/b.rs".to_string(), 1, 0),
                ("D6".to_string(), "rust/src/a.rs".to_string(), 2, 1),
            ]
        );
        assert!(rep.render().contains("D6 rust/src/a.rs: 2 -> 1"));

        // Grow: a new D1 bucket appears — refused.
        let grown = Baseline::from_findings(&[
            finding("D6", "rust/src/a.rs", 3),
            finding("D6", "rust/src/a.rs", 9),
            finding("D2", "rust/src/b.rs", 1),
            finding("D1", "rust/src/c.rs", 2),
        ]);
        assert!(committed.ratchet(&grown).grew);

        // Identical: empty report.
        let same = committed.ratchet(&committed.clone());
        assert!(!same.grew);
        assert!(same.rows.is_empty());
        assert_eq!(same.render(), "");
    }

    #[test]
    fn rejects_bad_documents() {
        assert!(Baseline::parse("not json").is_err());
        assert!(Baseline::parse("{\"version\": 9, \"entries\": []}").is_err());
        let missing = "{\"version\": 1, \"entries\": [{\"rule\": \"D1\"}]}";
        assert!(Baseline::parse(missing).is_err());
    }
}
