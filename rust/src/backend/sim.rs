//! Discrete-event simulation backend.
//!
//! Token generation costs come from the calibrated [`LatencyModel`];
//! token *identities* are synthetic (the scheduler never looks at them).
//! A request finishes when it reaches its ground-truth output length
//! from the workload trace — mirroring the paper's setting where the
//! server discovers response length only at EOS time.

use std::collections::HashMap;

use super::{BackendRequest, ExecutionBackend, PrefillJob, StepOutcome, TokenEvent};
use crate::coordinator::request::RequestId;
use crate::model::latency::LatencyModel;

#[derive(Debug, Clone)]
struct SimRequest {
    output_tokens: usize,
    generated: usize,
}

/// Simulation backend over a latency model.
#[derive(Debug)]
pub struct SimBackend {
    latency: LatencyModel,
    requests: HashMap<RequestId, SimRequest>,
}

impl SimBackend {
    pub fn new(latency: LatencyModel) -> Self {
        SimBackend { latency, requests: HashMap::new() }
    }

    pub fn latency_model(&self) -> &LatencyModel {
        &self.latency
    }

    fn gen_token(&mut self, id: RequestId) -> TokenEvent {
        // lint:allow(D6, decode of an unregistered request is a caller contract bug)
        let r = self.requests.get_mut(&id).expect("decode of unregistered request");
        r.generated += 1;
        TokenEvent { id, token: r.generated as u32, finished: r.generated >= r.output_tokens }
    }
}

impl ExecutionBackend for SimBackend {
    fn register(&mut self, req: BackendRequest) -> anyhow::Result<()> {
        self.requests.insert(
            req.id,
            SimRequest { output_tokens: req.output_tokens.max(1), generated: 0 },
        );
        Ok(())
    }

    fn prefill(&mut self, jobs: &[PrefillJob]) -> anyhow::Result<StepOutcome> {
        // Tokens restored from a parked session prefix skip prefill
        // compute and pay the (cheaper) host→device transfer instead —
        // the prefix-hit TTFT win of DESIGN.md §10.
        let compute: usize =
            jobs.iter().map(|j| j.context_tokens - j.cached_tokens.min(j.context_tokens)).sum();
        let cached: usize = jobs.iter().map(|j| j.cached_tokens).sum();
        let latency = self.latency.prefill(compute) + self.latency.swap(cached);
        // A prefill replay (recompute) does NOT re-emit already-delivered
        // tokens; it delivers the *next* token. The engine tracks what
        // was delivered; here we just generate one more.
        let tokens = jobs.iter().map(|j| self.gen_token(j.id)).collect();
        Ok(StepOutcome { latency, tokens })
    }

    fn decode(&mut self, batch: &[RequestId], total_ctx: usize) -> anyhow::Result<StepOutcome> {
        let latency = self.latency.decode(batch.len(), total_ctx);
        let tokens = batch.iter().map(|&id| self.gen_token(id)).collect();
        Ok(StepOutcome { latency, tokens })
    }

    fn swap_cost(&mut self, tokens: usize) -> f64 {
        self.latency.swap(tokens)
    }

    fn drop_kv(&mut self, _id: RequestId) {
        // KV accounting lives in the coordinator; generation progress is
        // retained (recompute replays context but not delivered tokens).
    }

    fn release(&mut self, id: RequestId) {
        self.requests.remove(&id);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::gpu::a100_4x;
    use crate::model::llm::opt_66b;

    fn backend() -> SimBackend {
        SimBackend::new(LatencyModel::for_deployment(&opt_66b(), &a100_4x()))
    }

    fn reg(b: &mut SimBackend, id: RequestId, out: usize) {
        b.register(BackendRequest { id, prompt: vec![], prompt_tokens: 10, output_tokens: out })
            .unwrap();
    }

    #[test]
    fn decode_generates_one_token_each() {
        let mut b = backend();
        reg(&mut b, 0, 3);
        reg(&mut b, 1, 1);
        let out = b.decode(&[0, 1], 20).unwrap();
        assert_eq!(out.tokens.len(), 2);
        assert!(!out.tokens[0].finished);
        assert!(out.tokens[1].finished, "output_tokens=1 finishes immediately");
        assert!(out.latency > 0.0);
    }

    #[test]
    fn finishes_at_ground_truth_length() {
        let mut b = backend();
        reg(&mut b, 0, 3);
        assert!(!b.decode(&[0], 10).unwrap().tokens[0].finished);
        assert!(!b.decode(&[0], 11).unwrap().tokens[0].finished);
        assert!(b.decode(&[0], 12).unwrap().tokens[0].finished);
    }

    #[test]
    fn prefill_latency_scales_with_tokens() {
        let mut b = backend();
        reg(&mut b, 0, 5);
        reg(&mut b, 1, 5);
        let small =
            b.prefill(&[PrefillJob { id: 0, context_tokens: 50, cached_tokens: 0 }]).unwrap();
        let large =
            b.prefill(&[PrefillJob { id: 1, context_tokens: 800, cached_tokens: 0 }]).unwrap();
        assert!(large.latency > small.latency);
        assert_eq!(small.tokens.len(), 1);
        assert_eq!(small.tokens[0].token, 1);
    }

    #[test]
    fn recompute_preserves_progress() {
        let mut b = backend();
        reg(&mut b, 0, 5);
        b.decode(&[0], 10).unwrap();
        b.decode(&[0], 11).unwrap();
        b.drop_kv(0); // recompute-preempt
        // Replaying prefill generates token #3, not #1.
        let out =
            b.prefill(&[PrefillJob { id: 0, context_tokens: 12, cached_tokens: 0 }]).unwrap();
        assert_eq!(out.tokens[0].token, 3);
    }

    #[test]
    fn cached_prefix_tokens_cost_transfer_not_compute() {
        let mut b = backend();
        reg(&mut b, 0, 5);
        reg(&mut b, 1, 5);
        let cold =
            b.prefill(&[PrefillJob { id: 0, context_tokens: 800, cached_tokens: 0 }]).unwrap();
        let hit = b
            .prefill(&[PrefillJob { id: 1, context_tokens: 800, cached_tokens: 600 }])
            .unwrap();
        // Transfer over PCIe is cheaper than recomputing the prefix.
        assert!(hit.latency < cold.latency, "hit {} !< cold {}", hit.latency, cold.latency);
        // And it still costs more than prefilling only the new suffix.
        let suffix =
            b.prefill(&[PrefillJob { id: 0, context_tokens: 200, cached_tokens: 0 }]).unwrap();
        assert!(hit.latency > suffix.latency);
    }

    #[test]
    fn swap_cost_positive_and_monotone() {
        let mut b = backend();
        assert!(b.swap_cost(100) > 0.0);
        assert!(b.swap_cost(1000) > b.swap_cost(100));
    }
}
