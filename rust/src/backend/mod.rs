//! Execution backends: where tokens actually come from.
//!
//! The engine (L3 coordinator) is generic over [`ExecutionBackend`] +
//! [`Clock`]; the paper's contribution code path is identical whether the
//! tokens come from:
//!
//! - [`sim::SimBackend`] — the calibrated discrete-event model standing
//!   in for OPT-13B…175B on A100/A40 nodes (virtual clock), or
//! - [`crate::runtime::PjrtBackend`] — the real tiny-OPT model compiled
//!   AOT from JAX/Pallas and executed via the PJRT C API (wall clock).

pub mod pjrt;
pub mod sim;

use crate::coordinator::request::RequestId;

/// Engine time source. Virtual for simulation, wall for real serving.
pub trait Clock {
    /// Current time in seconds (monotone).
    fn now(&self) -> f64;
    /// Account `dt` seconds of work. Virtual clocks jump; wall clocks
    /// ignore this (real work already took real time).
    fn advance(&mut self, dt: f64);
    /// Sleep/jump to an absolute time (≥ now), e.g. to the next arrival.
    fn advance_to(&mut self, t: f64);
}

/// Simulation clock: time is a number we control.
#[derive(Debug, Default, Clone)]
pub struct VirtualClock {
    t: f64,
}

impl Clock for VirtualClock {
    fn now(&self) -> f64 {
        self.t
    }
    fn advance(&mut self, dt: f64) {
        debug_assert!(dt >= 0.0);
        self.t += dt;
    }
    fn advance_to(&mut self, t: f64) {
        if t > self.t {
            self.t = t;
        }
    }
}

/// Wall clock anchored at creation.
#[derive(Debug)]
pub struct WallClock {
    start: std::time::Instant,
}

impl WallClock {
    pub fn new() -> Self {
        // lint:allow(D2, WallClock is the wall-domain Clock implementation itself)
        WallClock { start: std::time::Instant::now() }
    }
}

impl Default for WallClock {
    fn default() -> Self {
        Self::new()
    }
}

impl Clock for WallClock {
    fn now(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }
    fn advance(&mut self, _dt: f64) {
        // Real work already consumed real time.
    }
    fn advance_to(&mut self, t: f64) {
        let now = self.now();
        if t > now {
            std::thread::sleep(std::time::Duration::from_secs_f64(t - now));
        }
    }
}

/// A request registered with the backend at arrival time.
#[derive(Debug, Clone)]
pub struct BackendRequest {
    pub id: RequestId,
    /// Prompt token ids (real backend) — empty in simulation.
    pub prompt: Vec<u32>,
    /// Prompt length in tokens (authoritative for KV accounting).
    pub prompt_tokens: usize,
    /// Ground-truth output length (simulation EOS); real backends ignore
    /// it and detect EOS from the model.
    pub output_tokens: usize,
}

/// A prefill job: replay `context_tokens` of context for `id` (prompt +
/// any generated-then-dropped tokens for recompute preemption).
#[derive(Debug, Clone, Copy)]
pub struct PrefillJob {
    pub id: RequestId,
    pub context_tokens: usize,
    /// Leading tokens whose KV was claimed from a parked session prefix
    /// (DESIGN.md §10): the simulator charges a host→device transfer for
    /// them instead of prefill compute. 0 for ordinary prefills; the
    /// real PJRT backend has no prefix cache and ignores it.
    pub cached_tokens: usize,
}

/// One generated token event.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TokenEvent {
    pub id: RequestId,
    pub token: u32,
    /// True when this token ends the response (EOS / length reached).
    pub finished: bool,
}

/// Result of a prefill or decode step.
#[derive(Debug, Clone)]
pub struct StepOutcome {
    /// Seconds this step took (virtual or measured).
    pub latency: f64,
    /// One event per request in the step.
    pub tokens: Vec<TokenEvent>,
}

/// Token generation backend. All methods are infallible in simulation;
/// the PJRT backend surfaces runtime errors.
pub trait ExecutionBackend {
    /// Register a request on arrival.
    fn register(&mut self, req: BackendRequest) -> anyhow::Result<()>;

    /// Run one (batched) prefill pass; each job delivers the request's
    /// first token (vLLM-style prefill iteration).
    fn prefill(&mut self, jobs: &[PrefillJob]) -> anyhow::Result<StepOutcome>;

    /// Run one decode iteration over `batch`; every request generates
    /// exactly one token. `total_ctx` is the batch's total context
    /// length (for latency accounting).
    fn decode(&mut self, batch: &[RequestId], total_ctx: usize) -> anyhow::Result<StepOutcome>;

    /// Account a swap of `tokens` of KV state (either direction);
    /// returns the latency to charge.
    fn swap_cost(&mut self, tokens: usize) -> f64;

    /// Drop a request's generation state (on finish, or on recompute
    /// preemption drop of KV — the prompt stays registered so prefill
    /// can replay).
    fn drop_kv(&mut self, id: RequestId);

    /// Forget the request entirely (finished and recorded).
    fn release(&mut self, id: RequestId);

    /// Generated token ids so far, if the backend retains concrete
    /// token values (real backends streaming text). Simulators return
    /// `None` — callers streaming to clients substitute placeholders.
    fn generated_tokens(&self, _id: RequestId) -> Option<&[u32]> {
        None
    }

    /// Drop a finished request's retained token values once delivery is
    /// confirmed. No-op for backends that retain none.
    fn forget(&mut self, _id: RequestId) {}
}
