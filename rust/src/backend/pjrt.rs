//! Real-model execution backend over the PJRT runtime.
//!
//! Tokens come from the AOT-compiled tiny-OPT model (JAX + Pallas →
//! HLO → PJRT CPU). Latencies are real wall-clock measurements, which
//! is why this backend pairs with [`super::WallClock`].

use std::collections::HashMap;
use std::time::Instant;

use anyhow::{Context, Result};

use super::{BackendRequest, ExecutionBackend, PrefillJob, StepOutcome, TokenEvent};
use crate::coordinator::request::RequestId;
use crate::runtime::engine::{extract_seq, insert_seq, ModelRuntime};
use crate::runtime::sampler::{sample, Sampling};
use crate::util::rng::Rng;

/// Cached batch KV literals: when the running batch's membership is
/// unchanged between decode iterations (the common case), the previous
/// step's output KV feeds the next step directly, skipping the
/// host-side extract/insert copies that otherwise dominate decode time
/// (~3× speedup at b=16; see EXPERIMENTS.md §Perf).
struct BatchCache {
    ids: Vec<RequestId>,
    exec_b: usize,
    k: xla::Literal,
    v: xla::Literal,
}

struct PjrtRequest {
    prompt: Vec<u32>,
    generated: Vec<u32>,
    /// Max new tokens for this request (the workload's output length).
    max_new_tokens: usize,
    /// Per-sequence KV caches [L, H, S, d] flats; None when dropped
    /// (recompute preemption) or not yet prefilled.
    kv: Option<(Vec<f32>, Vec<f32>)>,
}

impl PjrtRequest {
    /// Position of the next token to be written into the KV cache.
    fn next_position(&self) -> usize {
        self.prompt.len() + self.generated.len()
    }
}

/// PJRT-backed execution.
pub struct PjrtBackend {
    runtime: ModelRuntime,
    requests: HashMap<RequestId, PjrtRequest>,
    sampling: Sampling,
    rng: Rng,
    cache: Option<BatchCache>,
    /// Generated tokens of finished requests. `release()` runs mid-tick,
    /// before the serving loop reads the final tokens, so they are
    /// retained here until the server calls [`PjrtBackend::forget`].
    finished: HashMap<RequestId, Vec<u32>>,
}

impl PjrtBackend {
    pub fn new(runtime: ModelRuntime, sampling: Sampling, seed: u64) -> Self {
        PjrtBackend {
            runtime,
            requests: HashMap::new(),
            sampling,
            rng: Rng::new(seed),
            cache: None,
            finished: HashMap::new(),
        }
    }

    /// Write the cached batch KV back into per-request stores (called
    /// before any operation that reads or drops per-request KV while a
    /// cache is live).
    fn flush_cache(&mut self) -> Result<()> {
        let Some(cache) = self.cache.take() else { return Ok(()) };
        let m = &self.runtime.meta;
        let k_all: Vec<f32> = cache.k.to_vec()?;
        let v_all: Vec<f32> = cache.v.to_vec()?;
        for (row, id) in cache.ids.iter().enumerate() {
            if let Some(r) = self.requests.get_mut(id) {
                r.kv = Some((
                    extract_seq(&k_all, row, cache.exec_b, m),
                    extract_seq(&v_all, row, cache.exec_b, m),
                ));
            }
        }
        Ok(())
    }

    /// Fast-path decode against the cached batch literals.
    fn decode_cached(&mut self, batch: &[RequestId]) -> Result<StepOutcome> {
        // lint:allow(D2, real-hardware step timing is the measurement itself)
        let t0 = Instant::now();
        let cache = self.cache.take().expect("decode_cached without cache");
        let b = cache.exec_b;
        let m_pad = self.runtime.meta.pad_token as i32;
        let mut tokens = vec![m_pad; b];
        let mut positions = vec![0i32; b];
        for (row, id) in batch.iter().enumerate() {
            let r = &self.requests[id];
            tokens[row] = *r.generated.last().unwrap_or(r.prompt.last().unwrap()) as i32;
            positions[row] = (r.next_position() - 1) as i32;
        }
        let (logits, k_new, v_new) =
            self.runtime.decode_literals(&tokens, &positions, cache.k, cache.v, b)?;
        self.cache = Some(BatchCache { ids: cache.ids, exec_b: b, k: k_new, v: v_new });
        let vocab = self.runtime.meta.vocab;
        let mut events = Vec::with_capacity(batch.len());
        for (row, id) in batch.iter().enumerate() {
            let tok = sample(&logits[row * vocab..(row + 1) * vocab], self.sampling, &mut self.rng);
            let r = self.requests.get_mut(id).unwrap();
            r.generated.push(tok);
            let finished = {
                let r = &self.requests[id];
                self.finished_after(r, tok)
            };
            events.push(TokenEvent { id: *id, token: tok, finished });
        }
        // Finished requests leave the batch next iteration; flush so
        // their rows aren't lost if the engine reads nothing further.
        if events.iter().any(|e| e.finished) {
            self.flush_cache()?;
        }
        Ok(StepOutcome { latency: t0.elapsed().as_secs_f64(), tokens: events })
    }

    pub fn runtime(&self) -> &ModelRuntime {
        &self.runtime
    }

    /// Generated token ids so far (for streaming decode to text).
    /// Remains available after the request finishes, until `forget()`.
    pub fn generated(&self, id: RequestId) -> Option<&[u32]> {
        self.requests
            .get(&id)
            .map(|r| r.generated.as_slice())
            .or_else(|| self.finished.get(&id).map(|v| v.as_slice()))
    }

    /// Drop a finished request's retained tokens (delivery confirmed).
    pub fn forget(&mut self, id: RequestId) {
        self.finished.remove(&id);
    }

    fn finished_after(&self, r: &PjrtRequest, token: u32) -> bool {
        token == self.runtime.meta.eos_token
            || r.generated.len() >= r.max_new_tokens
            || r.next_position() >= self.runtime.meta.max_seq
    }
}

impl PjrtBackend {
    /// Slow path for a batch that fits one executable: assemble batch
    /// literals from per-request KV, execute, keep the outputs as the
    /// new cache.
    fn decode_assemble_and_cache(&mut self, batch: &[RequestId]) -> Result<StepOutcome> {
        // lint:allow(D2, real-hardware step timing is the measurement itself)
        let t0 = Instant::now();
        let m = self.runtime.meta.clone();
        let b = self.runtime.decode_exec_batch(batch.len());
        let per_seq = m.kv_elems_per_seq();
        let mut tokens = vec![m.pad_token as i32; b];
        let mut positions = vec![0i32; b];
        let mut k_batch = vec![0f32; b * per_seq];
        let mut v_batch = vec![0f32; b * per_seq];
        for (row, id) in batch.iter().enumerate() {
            let r = self.requests.get_mut(id).with_context(|| format!("unknown req {id}"))?;
            let (k, v) = r.kv.take().with_context(|| format!("request {id} has no KV"))?;
            tokens[row] = *r.generated.last().unwrap_or(r.prompt.last().unwrap()) as i32;
            positions[row] = (r.next_position() - 1) as i32;
            insert_seq(&mut k_batch, &k, row, b, &m);
            insert_seq(&mut v_batch, &v, row, b, &m);
        }
        let kv_dims = [
            m.n_layers as i64,
            b as i64,
            m.n_heads as i64,
            m.max_seq as i64,
            m.d_head as i64,
        ];
        let k_lit = xla::Literal::vec1(&k_batch).reshape(&kv_dims)?;
        let v_lit = xla::Literal::vec1(&v_batch).reshape(&kv_dims)?;
        let (logits, k_new, v_new) =
            self.runtime.decode_literals(&tokens, &positions, k_lit, v_lit, b)?;
        self.cache =
            Some(BatchCache { ids: batch.to_vec(), exec_b: b, k: k_new, v: v_new });
        let mut events = Vec::with_capacity(batch.len());
        for (row, id) in batch.iter().enumerate() {
            let tok = sample(
                &logits[row * m.vocab..(row + 1) * m.vocab],
                self.sampling,
                &mut self.rng,
            );
            let r = self.requests.get_mut(id).unwrap();
            r.generated.push(tok);
            let finished = {
                let r = &self.requests[id];
                self.finished_after(r, tok)
            };
            events.push(TokenEvent { id: *id, token: tok, finished });
        }
        if events.iter().any(|e| e.finished) {
            self.flush_cache()?;
        }
        Ok(StepOutcome { latency: t0.elapsed().as_secs_f64(), tokens: events })
    }
}

impl ExecutionBackend for PjrtBackend {
    fn generated_tokens(&self, id: RequestId) -> Option<&[u32]> {
        PjrtBackend::generated(self, id)
    }

    fn forget(&mut self, id: RequestId) {
        PjrtBackend::forget(self, id);
    }

    fn register(&mut self, req: BackendRequest) -> Result<()> {
        let max_seq = self.runtime.meta.max_seq;
        anyhow::ensure!(
            req.prompt.len() < max_seq,
            "prompt of {} tokens exceeds context {}",
            req.prompt.len(),
            max_seq
        );
        anyhow::ensure!(!req.prompt.is_empty(), "empty prompt for request {}", req.id);
        self.requests.insert(
            req.id,
            PjrtRequest {
                prompt: req.prompt,
                generated: Vec::new(),
                max_new_tokens: req.output_tokens.max(1),
                kv: None,
            },
        );
        Ok(())
    }

    fn prefill(&mut self, jobs: &[PrefillJob]) -> Result<StepOutcome> {
        self.flush_cache()?;
        // lint:allow(D2, real-hardware step timing is the measurement itself)
        let t0 = Instant::now();
        // Replay context = prompt + already-generated (recompute case).
        let prompts: Vec<Vec<u32>> = jobs
            .iter()
            .map(|j| {
                let r = &self.requests[&j.id];
                let mut ctx = r.prompt.clone();
                ctx.extend_from_slice(&r.generated);
                ctx
            })
            .collect();
        let results = self.runtime.prefill(&prompts).context("prefill")?;
        let mut tokens = Vec::with_capacity(jobs.len());
        for (job, res) in jobs.iter().zip(results) {
            let r = self.requests.get_mut(&job.id).unwrap();
            r.kv = Some((res.k_cache, res.v_cache));
            let tok = sample(&res.logits, self.sampling, &mut self.rng);
            r.generated.push(tok);
            let finished = {
                let r = &self.requests[&job.id];
                self.finished_after(r, tok)
            };
            tokens.push(TokenEvent { id: job.id, token: tok, finished });
        }
        Ok(StepOutcome { latency: t0.elapsed().as_secs_f64(), tokens })
    }

    fn decode(&mut self, batch: &[RequestId], _total_ctx: usize) -> Result<StepOutcome> {
        // Fast path: batch membership unchanged since the last decode.
        if self
            .cache
            .as_ref()
            .is_some_and(|c| c.ids == batch && c.exec_b >= batch.len())
        {
            return self.decode_cached(batch);
        }
        self.flush_cache()?;
        // Membership changed (or first decode): assemble from the
        // per-request stores, then prime the cache from the outputs.
        if batch.len() <= self.runtime.max_decode_batch() {
            return self.decode_assemble_and_cache(batch);
        }
        // Oversized batch: chunked slow path (no caching).
        // lint:allow(D2, real-hardware step timing is the measurement itself)
        let t0 = Instant::now();
        // Assemble (last_token, position, kv) per sequence. The KV flats
        // are moved out to satisfy the borrow checker, then moved back.
        let mut staged: Vec<(RequestId, u32, usize, Vec<f32>, Vec<f32>)> = Vec::new();
        for &id in batch {
            let r = self.requests.get_mut(&id).with_context(|| format!("unknown req {id}"))?;
            let (k, v) = r.kv.take().with_context(|| format!("request {id} has no KV"))?;
            let last = *r.generated.last().unwrap_or(r.prompt.last().unwrap());
            // The last generated token sits at position next_position()-1;
            // decode writes it and attends over everything before it.
            let pos = r.next_position() - 1;
            staged.push((id, last, pos, k, v));
        }
        let entries: Vec<(u32, usize, &[f32], &[f32])> = staged
            .iter()
            .map(|(_, tok, pos, k, v)| (*tok, *pos, k.as_slice(), v.as_slice()))
            .collect();
        let results = self.runtime.decode(&entries).context("decode")?;
        let mut tokens = Vec::with_capacity(batch.len());
        for ((id, ..), (logits, k, v)) in staged.iter().zip(results) {
            let tok = sample(&logits, self.sampling, &mut self.rng);
            let r = self.requests.get_mut(id).unwrap();
            r.kv = Some((k, v));
            r.generated.push(tok);
            let finished = {
                let r = &self.requests[id];
                self.finished_after(r, tok)
            };
            tokens.push(TokenEvent { id: *id, token: tok, finished });
        }
        Ok(StepOutcome { latency: t0.elapsed().as_secs_f64(), tokens })
    }

    fn swap_cost(&mut self, _tokens: usize) -> f64 {
        // Host-to-host "swap" of CPU literals is effectively free; the
        // wall clock captures any real cost.
        0.0
    }

    fn drop_kv(&mut self, id: RequestId) {
        if self.cache.as_ref().is_some_and(|c| c.ids.contains(&id)) {
            let _ = self.flush_cache();
        }
        if let Some(r) = self.requests.get_mut(&id) {
            r.kv = None;
        }
    }

    fn release(&mut self, id: RequestId) {
        if self.cache.as_ref().is_some_and(|c| c.ids.contains(&id)) {
            let _ = self.flush_cache();
        }
        if let Some(r) = self.requests.remove(&id) {
            self.finished.insert(id, r.generated);
        }
    }
}
