//! QoE requirement specification (paper §2.2, §3.1).
//!
//! A request's *expected token delivery timeline* (TDT) is defined by two
//! numbers chosen by the application developer: the expected time to first
//! token (TTFT) and the expected token delivery speed (TDS).

/// Expected token delivery timeline of a request.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct QoeSpec {
    /// Expected time-to-first-token in seconds.
    pub ttft: f64,
    /// Expected token delivery speed in tokens/second (digestion speed).
    pub tds: f64,
}

impl QoeSpec {
    pub fn new(ttft: f64, tds: f64) -> Self {
        assert!(ttft >= 0.0, "ttft must be non-negative");
        assert!(tds > 0.0, "tds must be positive");
        QoeSpec { ttft, tds }
    }

    /// The expected cumulative-token curve T(t) = TDS·(t − TTFT), clamped
    /// at 0 below TTFT and (optionally) at the response length `cap`.
    pub fn expected_tokens_at(&self, t: f64, cap: Option<f64>) -> f64 {
        let raw = (self.tds * (t - self.ttft)).max(0.0);
        match cap {
            Some(l) => raw.min(l),
            None => raw,
        }
    }

    /// Closed-form ∫₀ᵗ min(T(u), cap) du — the denominator of Eq. 1.
    pub fn expected_area(&self, t: f64, cap: Option<f64>) -> f64 {
        if t <= self.ttft {
            return 0.0;
        }
        let ramp = t - self.ttft;
        match cap {
            Some(l) if l <= 0.0 => 0.0,
            Some(l) => {
                let t_cap = l / self.tds; // ramp duration until the cap
                if ramp <= t_cap {
                    0.5 * self.tds * ramp * ramp
                } else {
                    0.5 * self.tds * t_cap * t_cap + l * (ramp - t_cap)
                }
            }
            None => 0.5 * self.tds * ramp * ramp,
        }
    }
}

/// Average adult reading speed expressed in tokens/s (paper §2.2):
/// 200–236 WPM blended over age groups ≈ 4.8 tokens/s after the
/// word→token conversion ratio of ChatGPT's tokenizer.
pub const READING_TDS: f64 = 4.8;

/// Average speaking speed in tokens/s (paper §2.2): ≈150 WPM English
/// ≈ 3.3 tokens/s — the voice-chat service class.
pub const SPEAKING_TDS: f64 = 3.3;

/// Default expected TTFT used throughout the paper's evaluation (§6.1).
pub const DEFAULT_TTFT: f64 = 1.0;

/// Built-in service classes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ServiceClass {
    /// Raw-text chat: TTFT 1s, TDS = reading speed.
    TextChat,
    /// Voice chat (TTS readout): TTFT 1s, TDS = speaking speed.
    VoiceChat,
}

impl ServiceClass {
    pub fn spec(&self) -> QoeSpec {
        match self {
            ServiceClass::TextChat => QoeSpec::new(DEFAULT_TTFT, READING_TDS),
            ServiceClass::VoiceChat => QoeSpec::new(DEFAULT_TTFT, SPEAKING_TDS),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn expected_tokens_piecewise() {
        let s = QoeSpec::new(1.0, 4.0);
        assert_eq!(s.expected_tokens_at(0.5, None), 0.0);
        assert_eq!(s.expected_tokens_at(1.0, None), 0.0);
        assert_eq!(s.expected_tokens_at(2.0, None), 4.0);
        assert_eq!(s.expected_tokens_at(10.0, Some(8.0)), 8.0);
    }

    #[test]
    fn expected_area_uncapped() {
        let s = QoeSpec::new(1.0, 4.0);
        // From t=1 to t=3: triangle 0.5*4*2^2 = 8
        assert!((s.expected_area(3.0, None) - 8.0).abs() < 1e-12);
        assert_eq!(s.expected_area(0.5, None), 0.0);
    }

    #[test]
    fn expected_area_capped() {
        let s = QoeSpec::new(1.0, 4.0);
        // cap l=8 reached at t = 1 + 8/4 = 3. Area to t=5:
        // triangle 0.5*4*2^2 = 8, then flat 8 * 2 = 16 → 24.
        assert!((s.expected_area(5.0, Some(8.0)) - 24.0).abs() < 1e-12);
        // before cap: same as uncapped
        assert!((s.expected_area(2.0, Some(8.0)) - s.expected_area(2.0, None)).abs() < 1e-12);
        // zero-length response → zero expected area
        assert_eq!(s.expected_area(5.0, Some(0.0)), 0.0);
    }

    #[test]
    fn service_classes() {
        assert!(ServiceClass::TextChat.spec().tds > ServiceClass::VoiceChat.spec().tds);
        assert_eq!(ServiceClass::TextChat.spec().ttft, 1.0);
    }

    #[test]
    #[should_panic]
    fn rejects_zero_tds() {
        QoeSpec::new(1.0, 0.0);
    }
}
