//! Quality-of-Experience for text streaming services (paper §3.1).
//!
//! - [`spec`]: the expected token delivery timeline (TTFT + TDS).
//! - [`metric`]: the QoE metric of Eq. 1, computed incrementally, plus the
//!   analytic projector used by the scheduler's `Q_serve`/`Q_wait`.
//! - [`buffer`]: the client-side pacing token buffer (Fig. 8).

pub mod buffer;
pub mod metric;
pub mod spec;

pub use buffer::TokenBuffer;
pub use metric::{project, qoe_at, qoe_finished, DigestState};
pub use spec::{QoeSpec, ServiceClass};
