//! The QoE metric (paper Eq. 1) and its incremental computation.
//!
//! QoE compares two cumulative-token curves over the request lifetime
//! (time is measured from request *arrival*):
//!
//! - the **expected** curve `T(t) = TDS_exp · (t − TTFT_exp)`, capped at
//!   the response length `l`;
//! - the **actual digestion** curve `A(t)`: the user digests delivered
//!   tokens at a rate capped by the expected TDS (the client-side token
//!   buffer withholds faster deliveries), and can never digest more
//!   tokens than have been delivered.
//!
//! `QoE = clamp(∫A / ∫min(T,l), 0, 1)`, integrating to the time the user
//! finishes digesting the last token.
//!
//! [`DigestState`] maintains `A`'s integral *incrementally* (O(1) per
//! delivered token), which is what lets the scheduler evaluate
//! `Q_serve(B)`/`Q_wait` for hundreds of requests per iteration (paper
//! §4.2's efficiency requirement). [`project`] analytically extends a
//! state by a hypothetical constant-rate future delivery — the QoE
//! predictor behind Eq. 2.

use super::spec::QoeSpec;

/// Incremental state of the actual-digestion curve A(t).
///
/// All times are relative to the request's arrival (t = 0).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DigestState {
    /// Digestion speed cap (= the spec's expected TDS).
    tds: f64,
    /// Number of tokens delivered so far (the ceiling for `digested`).
    delivered: f64,
    /// Continuous count of tokens digested as of `last_t`.
    digested: f64,
    /// Time of the last state advance.
    last_t: f64,
    /// Accumulated ∫₀^last_t A(u) du.
    area: f64,
}

impl DigestState {
    pub fn new(spec: &QoeSpec) -> Self {
        DigestState { tds: spec.tds, delivered: 0.0, digested: 0.0, last_t: 0.0, area: 0.0 }
    }

    pub fn delivered(&self) -> f64 {
        self.delivered
    }
    pub fn digested(&self) -> f64 {
        self.digested
    }
    pub fn last_t(&self) -> f64 {
        self.last_t
    }

    /// Tokens sitting in the client buffer (delivered, not yet digested).
    pub fn buffered(&self) -> f64 {
        self.delivered - self.digested
    }

    /// Advance the digestion process to absolute request-time `t`.
    pub fn advance_to(&mut self, t: f64) {
        if t <= self.last_t {
            return;
        }
        let dt = t - self.last_t;
        let headroom = self.delivered - self.digested;
        let ramp_time = (headroom / self.tds).min(dt);
        // Trapezoid for the ramping part, then flat at the delivery cap.
        let ramp_gain = self.tds * ramp_time;
        self.area += (self.digested + 0.5 * ramp_gain) * ramp_time;
        self.digested += ramp_gain;
        self.area += self.digested * (dt - ramp_time);
        self.last_t = t;
    }

    /// Record a token delivered at request-time `t` (must be ≥ last event).
    pub fn deliver(&mut self, t: f64) {
        self.advance_to(t);
        self.delivered += 1.0;
    }

    /// Record `n` tokens delivered at request-time `t` at once.
    pub fn deliver_n(&mut self, t: f64, n: usize) {
        self.advance_to(t);
        self.delivered += n as f64;
    }

    /// Time at which digestion of everything delivered so far completes.
    pub fn digest_end(&self) -> f64 {
        self.last_t + (self.delivered - self.digested) / self.tds
    }

    /// ∫₀ᵗ A(u) du for `t ≥ last_t`, without mutating (analytic extension).
    pub fn area_at(&self, t: f64) -> f64 {
        if t <= self.last_t {
            // Callers should only ask about the future; clamp defensively.
            return self.area;
        }
        let dt = t - self.last_t;
        let headroom = self.delivered - self.digested;
        let ramp_time = (headroom / self.tds).min(dt);
        let ramp_gain = self.tds * ramp_time;
        let mut area = self.area + (self.digested + 0.5 * ramp_gain) * ramp_time;
        area += (self.digested + ramp_gain) * (dt - ramp_time);
        area
    }
}

/// QoE of a *finished* request: integrate both curves to the time the
/// user digests the final token (≥ the last delivery time).
///
/// `response_len` is the total number of generated tokens `l` in Eq. 1.
pub fn qoe_finished(spec: &QoeSpec, state: &DigestState, response_len: usize) -> f64 {
    if response_len == 0 {
        return 1.0;
    }
    debug_assert!(
        (state.delivered - response_len as f64).abs() < 1e-9,
        "all tokens must be delivered before computing final QoE"
    );
    let t_end = state.digest_end();
    qoe_at(spec, state, t_end, Some(response_len as f64))
}

/// QoE evaluated at an arbitrary horizon `t` (used mid-flight and by the
/// scheduler's predictor). `cap` is the response length if known.
pub fn qoe_at(spec: &QoeSpec, state: &DigestState, t: f64, cap: Option<f64>) -> f64 {
    let expected = spec.expected_area(t, cap);
    if expected <= 0.0 {
        // The user expects nothing yet — service cannot be late.
        return 1.0;
    }
    let actual = state.area_at(t);
    (actual / expected).clamp(0.0, 1.0)
}

/// QoE with the optional TTFT-stress penalty (paper §3.1):
/// `α^(TTFT_actual − TTFT_expected) · S_a/S_e` with α ∈ [0, 1].
/// `ttft_actual` is None when no token has been delivered yet.
pub fn qoe_with_ttft_penalty(
    spec: &QoeSpec,
    state: &DigestState,
    t: f64,
    cap: Option<f64>,
    alpha: f64,
    ttft_actual: Option<f64>,
) -> f64 {
    assert!((0.0..=1.0).contains(&alpha));
    let base = qoe_at(spec, state, t, cap);
    let lateness = match ttft_actual {
        Some(a) => (a - spec.ttft).max(0.0),
        None => (t - spec.ttft).max(0.0), // still waiting: lateness grows
    };
    alpha.powf(lateness) * base
}

/// Analytically project a digest state forward under a hypothetical
/// constant-rate token delivery, returning the projected state.
///
/// * `rate`: delivery rate in tokens/s starting after `start_delay`
///   (0 = no future delivery, i.e. the `Q_wait` scenario).
/// * `start_delay`: seconds after `state.last_t` before the first future
///   token (prefill / swap-in latency for a not-yet-running request).
/// * `horizon`: absolute request-time to project to (≥ `state.last_t`).
///
/// The future delivery is modeled as a continuous ramp — exact in the
/// limit of per-iteration token granularity, and what makes the
/// scheduler's per-request prediction O(1).
pub fn project(state: &DigestState, rate: f64, start_delay: f64, horizon: f64) -> DigestState {
    let mut s = state.clone();
    if horizon <= s.last_t {
        return s;
    }
    let t_start = s.last_t + start_delay.max(0.0);
    if rate <= 0.0 || t_start >= horizon {
        s.advance_to(horizon);
        return s;
    }
    // Phase 1: no new deliveries until t_start.
    s.advance_to(t_start);
    // Phase 2: delivery ramp at `rate`, digestion at min(tds, available).
    // If there is buffered backlog, digestion runs at tds until the
    // backlog drains (if rate < tds) or forever (if rate ≥ tds).
    let dt = horizon - t_start;
    let digest_rate_capped = s.tds.min(rate);
    let backlog = s.delivered - s.digested;
    if rate >= s.tds {
        // Delivery outpaces digestion: digestion ramps at tds throughout.
        let gain = s.tds * dt;
        s.area += (s.digested + 0.5 * gain) * dt;
        s.digested += gain;
        s.delivered += rate * dt;
        s.last_t = horizon;
        return s;
    }
    // rate < tds: digest at tds while backlog lasts, then at `rate`.
    // Backlog drains at (tds - rate) per second.
    let drain_time = if backlog > 0.0 { backlog / (s.tds - rate) } else { 0.0 };
    let t1 = drain_time.min(dt);
    if t1 > 0.0 {
        let gain = s.tds * t1;
        s.area += (s.digested + 0.5 * gain) * t1;
        s.digested += gain;
        s.delivered += rate * t1;
    }
    let t2 = dt - t1;
    if t2 > 0.0 {
        let gain = digest_rate_capped * t2;
        s.area += (s.digested + 0.5 * gain) * t2;
        s.digested += gain;
        s.delivered += rate * t2;
    }
    s.last_t = horizon;
    s
}

/// Fast path for the scheduler's inner loop: ∫₀^horizon A(u) du under a
/// hypothetical constant-rate delivery, without materializing the
/// projected state. Exactly `project(...).area_at(horizon)` (tested
/// against it) but ~2× cheaper — this runs N × |B-grid| times per
/// scheduling iteration (see EXPERIMENTS.md §Perf).
#[inline]
pub fn projected_area(state: &DigestState, rate: f64, start_delay: f64, horizon: f64) -> f64 {
    if horizon <= state.last_t {
        return state.area;
    }
    let tds = state.tds;
    let t_start = state.last_t + start_delay.max(0.0);
    if rate <= 0.0 || t_start >= horizon {
        return state.area_at(horizon);
    }
    // Phase 1: drain the existing backlog with no new deliveries.
    let mut digested = state.digested;
    let mut area = state.area;
    {
        let dt = t_start - state.last_t;
        let headroom = state.delivered - digested;
        let ramp_time = (headroom / tds).min(dt);
        let ramp_gain = tds * ramp_time;
        area += (digested + 0.5 * ramp_gain) * ramp_time;
        digested += ramp_gain;
        area += digested * (dt - ramp_time);
    }
    let dt = horizon - t_start;
    if rate >= tds {
        let gain = tds * dt;
        return area + (digested + 0.5 * gain) * dt;
    }
    // rate < tds: digest at tds while the backlog lasts, then at rate.
    let backlog = (state.delivered + 0.0) - digested; // deliveries resume
    let drain_time = if backlog > 0.0 { backlog / (tds - rate) } else { 0.0 };
    let t1 = drain_time.min(dt);
    if t1 > 0.0 {
        let gain = tds * t1;
        area += (digested + 0.5 * gain) * t1;
        digested += gain;
    }
    let t2 = dt - t1;
    if t2 > 0.0 {
        let gain = rate * t2;
        area += (digested + 0.5 * gain) * t2;
    }
    area
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::testing::assert_close;

    fn spec() -> QoeSpec {
        QoeSpec::new(1.0, 2.0) // expect first token at 1s, 2 tok/s
    }

    /// Oracle: numerically integrate A(t) from explicit delivery times by
    /// fine-grained stepping, for cross-checking the incremental math.
    fn area_oracle(spec: &QoeSpec, deliveries: &[f64], t_end: f64) -> f64 {
        let n_steps = 400_000;
        let dt = t_end / n_steps as f64;
        let mut digested = 0.0f64;
        let mut area = 0.0;
        for i in 0..n_steps {
            let t = (i as f64 + 0.5) * dt;
            let delivered = deliveries.iter().filter(|&&d| d <= t).count() as f64;
            digested = (digested + spec.tds * dt).min(delivered);
            area += digested * dt;
        }
        area
    }

    #[test]
    fn perfect_delivery_gives_qoe_one() {
        // Tokens arrive exactly on the expected timeline.
        let sp = spec();
        let mut st = DigestState::new(&sp);
        let l = 10usize;
        for i in 0..l {
            // Token i must arrive when T(t) crosses i (the delivered
            // staircase must stay ≥ the continuous ramp): t = ttft + i/tds.
            st.deliver(sp.ttft + i as f64 / sp.tds);
        }
        let q = qoe_finished(&sp, &st, l);
        assert!(q > 0.99, "q = {q}");
    }

    #[test]
    fn early_fast_delivery_clamps_to_one() {
        let sp = spec();
        let mut st = DigestState::new(&sp);
        st.deliver_n(0.1, 10); // burst: everything at t=0.1
        let q = qoe_finished(&sp, &st, 10);
        assert_eq!(q, 1.0);
    }

    #[test]
    fn late_delivery_lowers_qoe() {
        let sp = spec();
        // Same TDS but TTFT doubles expectations.
        let mut late = DigestState::new(&sp);
        for i in 0..10 {
            late.deliver(3.0 + (i + 1) as f64 / sp.tds);
        }
        let q_late = qoe_finished(&sp, &late, 10);
        assert!(q_late < 0.9, "late TTFT should hurt, q = {q_late}");

        // Slower TDS with on-time TTFT also hurts.
        let mut slow = DigestState::new(&sp);
        for i in 0..10 {
            slow.deliver(sp.ttft + (i + 1) as f64 / (sp.tds / 2.0));
        }
        let q_slow = qoe_finished(&sp, &slow, 10);
        assert!(q_slow < 0.9, "slow TDS should hurt, q = {q_slow}");
    }

    #[test]
    fn fig2_ordering() {
        // Paper Fig. 2: requests 1 & 2 satisfying (QoE 1); request 3
        // frustrating; request 4 worse (fewer tokens early, same TTFT and
        // same average latency).
        let sp = QoeSpec::new(1.0, 1.0);
        let l = 8usize;

        // r1: exactly expected pace (token i at ttft + i/tds).
        let mut r1 = DigestState::new(&sp);
        for i in 0..l {
            r1.deliver(1.0 + i as f64);
        }
        // r2: initial burst then ahead of schedule.
        let mut r2 = DigestState::new(&sp);
        r2.deliver_n(0.5, 4);
        for i in 4..l {
            r2.deliver(0.5 + (i - 3) as f64);
        }
        // r3: correct TTFT but tokens at half speed.
        let mut r3 = DigestState::new(&sp);
        for i in 0..l {
            r3.deliver(1.0 + 2.0 * i as f64);
        }
        // r4: same TTFT (first token at 3) and same completion time as r3
        // but back-loaded: almost everything arrives at the end.
        let mut r4 = DigestState::new(&sp);
        r4.deliver(1.0);
        for i in 1..l {
            let _ = i;
        }
        r4.deliver_n(2.0 + 2.0 * l as f64, l - 1);

        let q1 = qoe_finished(&sp, &r1, l);
        let q2 = qoe_finished(&sp, &r2, l);
        let q3 = qoe_finished(&sp, &r3, l);
        let q4 = qoe_finished(&sp, &r4, l);
        assert!(q1 > 0.99 && q2 > 0.99, "q1={q1} q2={q2}");
        assert!(q3 < 0.95, "q3={q3}");
        assert!(q4 < q3, "q4={q4} should be < q3={q3}");
    }

    #[test]
    fn incremental_area_matches_oracle() {
        let sp = spec();
        let deliveries = [0.9, 1.0, 1.05, 2.5, 2.5, 2.5, 6.0, 6.1, 7.3, 9.0];
        let mut st = DigestState::new(&sp);
        for &d in &deliveries {
            st.deliver(d);
        }
        let t_end = st.digest_end().max(10.0);
        st.advance_to(t_end);
        let oracle = area_oracle(&sp, &deliveries, t_end);
        assert_close(st.area_at(t_end), oracle, 1e-3);
    }

    #[test]
    fn buffered_token_accounting() {
        let sp = spec(); // tds = 2
        let mut st = DigestState::new(&sp);
        st.deliver_n(0.0, 6);
        assert_close(st.buffered(), 6.0, 1e-12);
        st.advance_to(1.0); // digests 2 tokens
        assert_close(st.buffered(), 4.0, 1e-9);
        assert_close(st.digested(), 2.0, 1e-9);
        st.advance_to(10.0); // all digested by t=3
        assert_close(st.digested(), 6.0, 1e-9);
        assert_close(st.digest_end(), 10.0, 1e-9);
    }

    #[test]
    fn qoe_before_expected_ttft_is_one() {
        let sp = spec();
        let st = DigestState::new(&sp);
        assert_eq!(qoe_at(&sp, &st, 0.5, None), 1.0);
        // After expected TTFT with nothing delivered, QoE collapses to 0.
        assert_eq!(qoe_at(&sp, &st, 2.0, None), 0.0);
    }

    #[test]
    fn zero_length_response_is_perfect() {
        let sp = spec();
        let st = DigestState::new(&sp);
        assert_eq!(qoe_finished(&sp, &st, 0), 1.0);
    }

    #[test]
    fn ttft_penalty_variant() {
        let sp = spec();
        let mut st = DigestState::new(&sp);
        st.deliver_n(3.0, 4); // 2s late
        st.advance_to(6.0);
        let base = qoe_at(&sp, &st, 6.0, Some(4.0));
        let penalized = qoe_with_ttft_penalty(&sp, &st, 6.0, Some(4.0), 0.5, Some(3.0));
        assert_close(penalized, base * 0.25, 1e-9); // 0.5^2
        // alpha = 1 is a no-op.
        let same = qoe_with_ttft_penalty(&sp, &st, 6.0, Some(4.0), 1.0, Some(3.0));
        assert_close(same, base, 1e-12);
    }

    #[test]
    fn project_matches_explicit_delivery() {
        let sp = spec(); // tds = 2
        let mut st = DigestState::new(&sp);
        st.deliver(1.0);
        st.deliver(1.5);

        // Project 4 seconds of delivery at 1 tok/s (slower than tds).
        let proj = project(&st, 1.0, 0.0, 5.5);

        // Oracle: explicit deliveries every 1s — use fine-grained
        // continuous comparison instead (the projector is continuous).
        // Continuous check: delivered = 2 + 4*1 = 6.
        assert_close(proj.delivered(), 6.0, 1e-9);
        assert!(proj.area_at(5.5) > st.area_at(5.5));
        // Digestion can't exceed delivery.
        assert!(proj.digested() <= proj.delivered() + 1e-9);
    }

    #[test]
    fn project_with_zero_rate_is_plain_advance() {
        let sp = spec();
        let mut st = DigestState::new(&sp);
        st.deliver_n(1.0, 3);
        let a = project(&st, 0.0, 0.0, 4.0);
        let mut b = st.clone();
        b.advance_to(4.0);
        assert_eq!(a, b);
    }

    #[test]
    fn project_fast_rate_digests_at_tds() {
        let sp = spec(); // tds 2
        let st = DigestState::new(&sp);
        let proj = project(&st, 10.0, 0.5, 2.5); // after 0.5s delay, 2s of fast delivery
        assert_close(proj.digested(), 2.0 * 2.0, 1e-9);
        assert_close(proj.delivered(), 10.0 * 2.0, 1e-9);
    }

    #[test]
    fn project_start_delay_past_horizon() {
        let sp = spec();
        let mut st = DigestState::new(&sp);
        st.deliver(0.5);
        let proj = project(&st, 5.0, 10.0, 3.0);
        let mut adv = st.clone();
        adv.advance_to(3.0);
        assert_eq!(proj, adv);
    }

    #[test]
    fn project_backlog_drain_then_rate_limited() {
        let sp = spec(); // tds 2
        let mut st = DigestState::new(&sp);
        st.deliver_n(0.0, 4); // backlog 4 tokens
        // rate 1 < tds 2: backlog drains at 1 tok/s → 4s; horizon 10.
        let proj = project(&st, 1.0, 0.0, 10.0);
        // After drain: digested = delivered. Total delivered = 4 + 10 = 14.
        assert_close(proj.delivered(), 14.0, 1e-9);
        // Digested: 2 tok/s for 4s = 8, then 1 tok/s for 6s = 6 → 14.
        assert_close(proj.digested(), 14.0, 1e-9);
    }

    #[test]
    fn projected_area_matches_project() {
        // Fast path ≡ project().area_at() across regimes.
        let sp = spec(); // tds 2
        let mut st = DigestState::new(&sp);
        st.deliver(0.7);
        st.deliver_n(1.1, 5);
        for &(rate, delay, horizon) in &[
            (0.0, 0.0, 6.0),
            (1.0, 0.0, 8.0),   // rate < tds, backlog drain
            (3.5, 0.0, 8.0),   // rate > tds
            (1.0, 2.0, 8.0),   // start delay
            (5.0, 10.0, 6.0),  // delay past horizon
            (2.0, 0.5, 1.0),   // horizon before last_t? (1.0 < 1.1)
        ] {
            let slow = project(&st, rate, delay, horizon).area_at(horizon);
            let fast = projected_area(&st, rate, delay, horizon);
            assert_close(fast, slow, 1e-9);
        }
    }

    #[test]
    fn qoe_at_zero_cap_and_ttft_boundary_edges() {
        // Pinned now that arrival times come from the client side: the
        // delivery layer can push every arrival past the expected-TTFT
        // boundary, so the boundary itself must be well-defined.
        let sp = spec(); // ttft 1, tds 2
        let mut st = DigestState::new(&sp);
        st.deliver_n(0.5, 3);
        // Zero-length cap: the user expects nothing — perfect service.
        assert_eq!(qoe_at(&sp, &st, 5.0, Some(0.0)), 1.0);
        // Exactly at the expected TTFT the expected area is still zero.
        assert_eq!(qoe_at(&sp, &DigestState::new(&sp), sp.ttft, None), 1.0);
        // Epsilon past it with nothing delivered, QoE collapses.
        assert_eq!(qoe_at(&sp, &DigestState::new(&sp), sp.ttft + 1e-9, None), 0.0);
    }

    #[test]
    fn ttft_penalty_edges() {
        let sp = spec();
        let mut st = DigestState::new(&sp);
        st.deliver_n(1.0, 4);
        st.advance_to(4.0);
        let base = qoe_at(&sp, &st, 4.0, Some(4.0));
        // On-time first token: any alpha is a no-op (alpha^0 == 1,
        // including alpha = 0, since 0^0 == 1 in IEEE powf).
        for alpha in [0.0, 0.5, 1.0] {
            let q = qoe_with_ttft_penalty(&sp, &st, 4.0, Some(4.0), alpha, Some(1.0));
            assert_close(q, base, 1e-12);
        }
        // Still waiting exactly at the boundary: lateness 0, no penalty.
        let empty = DigestState::new(&sp);
        assert_eq!(qoe_with_ttft_penalty(&sp, &empty, sp.ttft, None, 0.5, None), 1.0);
        // alpha = 0 annihilates any actual lateness.
        assert_eq!(qoe_with_ttft_penalty(&sp, &st, 4.0, Some(4.0), 0.0, Some(3.0)), 0.0);
    }

    #[test]
    fn near_zero_tds_is_stable() {
        // QoeSpec rejects tds == 0 outright (pinned in spec.rs); the
        // smallest usable digestion speeds must still produce finite,
        // in-range QoE rather than overflow the ramp arithmetic.
        let sp = QoeSpec::new(1.0, 1e-9);
        let mut st = DigestState::new(&sp);
        st.deliver_n(0.5, 3);
        let q = qoe_at(&sp, &st, 2.0, Some(3.0));
        assert!((0.0..=1.0).contains(&q), "q = {q}");
        assert!(q.is_finite());
    }

    #[test]
    fn qoe_monotone_in_lateness() {
        // Property: shifting every delivery later can only reduce QoE.
        let sp = spec();
        let base: Vec<f64> = (0..12).map(|i| 1.0 + 0.5 * i as f64).collect();
        let mut prev = f64::INFINITY;
        for shift in [0.0, 0.5, 1.0, 2.0, 4.0] {
            let mut st = DigestState::new(&sp);
            for &d in &base {
                st.deliver(d + shift);
            }
            let q = qoe_finished(&sp, &st, 12);
            assert!(q <= prev + 1e-9, "shift {shift}: {q} > {prev}");
            prev = q;
        }
    }
}
