//! Client-side token buffer (paper §5, Fig. 8).
//!
//! The server streams tokens as fast as it generates them (possibly in
//! bursts, possibly pausing the request entirely while it is preempted).
//! The client-side buffer withholds excess tokens and releases them at
//! the user's expected TDS, so the user sees a smooth timeline that also
//! absorbs network jitter. The server is aware of the buffer: a request
//! with a deep buffer is a preemption candidate.

use super::spec::QoeSpec;

/// One buffered/displayed token with its timing.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TokenTiming {
    /// When the server delivered the token to the client (request-time s).
    pub delivered_at: f64,
    /// When the buffer released it for display (request-time s).
    pub displayed_at: f64,
}

/// Paces token display at the expected TDS.
#[derive(Debug, Clone)]
pub struct TokenBuffer {
    /// Minimum spacing between displayed tokens = 1 / TDS.
    interval: f64,
    timings: Vec<TokenTiming>,
    /// Display time of the most recently scheduled token.
    last_display: f64,
}

impl TokenBuffer {
    pub fn new(spec: &QoeSpec) -> Self {
        TokenBuffer { interval: 1.0 / spec.tds, timings: Vec::new(), last_display: f64::NEG_INFINITY }
    }

    /// Record a token arriving from the server at time `t`; returns its
    /// scheduled display time. Delivery times must be non-decreasing
    /// (tokens arrive in stream order) — `depth_at` relies on it.
    pub fn push(&mut self, t: f64) -> f64 {
        if let Some(last) = self.timings.last() {
            debug_assert!(t >= last.delivered_at, "tokens must be pushed in delivery order");
        }
        // Display immediately if the pacing interval since the previous
        // token has already elapsed, else queue behind it.
        let display = t.max(self.last_display + self.interval);
        self.last_display = display;
        self.timings.push(TokenTiming { delivered_at: t, displayed_at: display });
        display
    }

    /// Number of tokens still undisplayed ("in the buffer") at time `t`.
    ///
    /// Both timing columns are non-decreasing in push order (delivery by
    /// the `push` precondition, display by construction), so the depth
    /// is the gap between two binary searches — O(log n) per query
    /// instead of the full O(n) scan, which went quadratic when the
    /// scheduler polled buffer depth per generated token.
    pub fn depth_at(&self, t: f64) -> usize {
        let delivered = self.timings.partition_point(|tt| tt.delivered_at <= t);
        let displayed = self.timings.partition_point(|tt| tt.displayed_at <= t);
        // displayed_at ≥ delivered_at per token, so `displayed` never
        // exceeds `delivered`.
        delivered - displayed
    }

    /// All token timings recorded so far.
    pub fn timings(&self) -> &[TokenTiming] {
        &self.timings
    }

    /// Display timestamps only (the user-visible TDT).
    pub fn display_times(&self) -> Vec<f64> {
        self.timings.iter().map(|t| t.displayed_at).collect()
    }

    /// The buffer's current drain deadline: when it would run empty if the
    /// server stopped sending now. The server can safely preempt the
    /// request until roughly this time without hurting QoE.
    pub fn drain_deadline(&self) -> f64 {
        self.last_display
    }

    pub fn len(&self) -> usize {
        self.timings.len()
    }
    pub fn is_empty(&self) -> bool {
        self.timings.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::qoe::spec::QoeSpec;

    fn buf() -> TokenBuffer {
        TokenBuffer::new(&QoeSpec::new(1.0, 2.0)) // 0.5s interval
    }

    #[test]
    fn paces_burst_delivery() {
        let mut b = buf();
        // 4 tokens all at t=1.0 → displayed at 1.0, 1.5, 2.0, 2.5
        for _ in 0..4 {
            b.push(1.0);
        }
        let d = b.display_times();
        assert_eq!(d, vec![1.0, 1.5, 2.0, 2.5]);
    }

    #[test]
    fn slow_delivery_passes_through() {
        let mut b = buf();
        assert_eq!(b.push(1.0), 1.0);
        assert_eq!(b.push(3.0), 3.0); // gap larger than interval: immediate
    }

    #[test]
    fn depth_tracks_buffered_tokens() {
        let mut b = buf();
        for _ in 0..4 {
            b.push(1.0);
        }
        assert_eq!(b.depth_at(1.1), 3); // first displayed at 1.0
        assert_eq!(b.depth_at(1.6), 2);
        assert_eq!(b.depth_at(3.0), 0);
    }

    #[test]
    fn depth_matches_linear_scan() {
        // The binary-search depth must agree with the original O(n)
        // definition at arbitrary query times, including boundaries.
        let mut b = TokenBuffer::new(&QoeSpec::new(1.0, 4.0));
        let mut rng = crate::util::rng::Rng::new(17);
        let mut t = 0.0;
        for _ in 0..500 {
            t += rng.exponential(8.0); // bursty-ish deliveries
            b.push(t);
        }
        let scan = |q: f64| {
            b.timings()
                .iter()
                .filter(|tt| tt.delivered_at <= q && tt.displayed_at > q)
                .count()
        };
        let mut q = 0.0;
        for _ in 0..2000 {
            q += rng.exponential(18.0);
            assert_eq!(b.depth_at(q), scan(q), "depth diverged at t={q}");
        }
        // Exact boundary instants (delivery == query, display == query).
        for tt in b.timings().iter().step_by(37) {
            for q in [tt.delivered_at, tt.displayed_at] {
                assert_eq!(b.depth_at(q), scan(q), "boundary t={q}");
            }
        }
    }

    #[test]
    fn absorbs_preemption_gap() {
        // Burst of 6, then a 2.5s server pause, then more: the user-visible
        // timeline stays smooth through the pause (Fig. 8's story).
        let mut b = buf();
        for _ in 0..6 {
            b.push(1.0);
        }
        // displayed at 1.0..3.5; server silent until 3.5, then resumes
        let d7 = b.push(3.5);
        assert_eq!(d7, 4.0); // keeps exact pacing: no visible stall
        let gaps: Vec<f64> = b
            .display_times()
            .windows(2)
            .map(|w| w[1] - w[0])
            .collect();
        assert!(gaps.iter().all(|g| (g - 0.5).abs() < 1e-9));
    }

    #[test]
    fn drain_deadline_advances() {
        let mut b = buf();
        b.push(1.0);
        b.push(1.0);
        assert_eq!(b.drain_deadline(), 1.5);
    }
}
