//! The QoE-aware serving gateway — the system's front door.
//!
//! The paper optimizes QoE *inside* one engine and explicitly scopes
//! out the front-end ("cluster-level load balancing ... done
//! separately", §5). This subsystem builds that front end, because QoE
//! is also won or lost before a request ever reaches a scheduler:
//!
//! - [`admission`] — estimate each arriving request's expected QoE gain
//!   and marginal resource cost and admit, defer, or reject it with a
//!   structured reason;
//! - [`pacing`] — shape token delivery at each request's digestion
//!   speed (plus a lead buffer), so the overfast surplus becomes
//!   scheduler slack instead of unread tokens on the wire;
//! - [`surge`] — a windowed arrival-rate detector that switches the
//!   gateway between its permissive normal mode and load-shedding
//!   surge mode (with hysteresis);
//! - [`Gateway`] — the orchestrator, wrapping either a single simulated
//!   [`Engine`] or a [`Cluster`] behind one submit/advance API, with
//!   surge-aware routing-policy override for clusters.
//!
//! The live TCP server ([`crate::server`]) reuses the same components
//! (admission controller, surge detector, per-request pacers) around
//! its real-model engine.

pub mod admission;
pub mod pacing;
pub mod surge;

pub use admission::{
    AdmissionConfig, AdmissionController, AdmissionDecision, RejectReason, ReplicaState,
};
pub use pacing::{pace_times, PacingConfig, TokenPacer};
pub use surge::{LoadMode, SurgeConfig, SurgeDetector};

use std::collections::VecDeque;

use anyhow::Result;

use crate::backend::sim::SimBackend;
use crate::backend::{Clock, ExecutionBackend, VirtualClock};
use crate::cluster::{Cluster, RoutingPolicy};
use crate::coordinator::engine::Engine;
use crate::coordinator::metrics::{Metrics, RequestRecord};
use crate::qoe::metric::{qoe_finished, DigestState};
use crate::qoe::spec::QoeSpec;
use crate::workload::RequestSpec;

/// Gateway configuration.
#[derive(Debug, Clone)]
pub struct GatewayConfig {
    pub admission_enabled: bool,
    pub pacing_enabled: bool,
    pub admission: AdmissionConfig,
    pub pacing: PacingConfig,
    pub surge: SurgeConfig,
    /// Routing-policy override while in surge mode (cluster targets
    /// only): spread load instead of QoE-greedy placement.
    pub surge_routing: Option<RoutingPolicy>,
}

impl Default for GatewayConfig {
    fn default() -> Self {
        GatewayConfig {
            admission_enabled: true,
            pacing_enabled: true,
            admission: AdmissionConfig::default(),
            pacing: PacingConfig::default(),
            surge: SurgeConfig::default(),
            surge_routing: Some(RoutingPolicy::LeastLoaded),
        }
    }
}

/// Snapshot one engine's state for admission control. Shared by the sim
/// targets below and the live server's real-model engine.
pub fn engine_state<B: ExecutionBackend, C: Clock>(e: &Engine<B, C>) -> ReplicaState {
    let active = e.active_count();
    let avg_ctx = e.avg_active_context().max(64);
    let kv_cap = e.kv().device_capacity_tokens();
    // Fair-share delivery speed for one more request: the batch is
    // bounded by KV capacity; beyond it, active requests time-share.
    let kv_batch_cap = (kv_cap / avg_ctx).max(1);
    let batch = (active + 1).min(kv_batch_cap);
    let share =
        e.latency().tokens_per_sec(batch, avg_ctx) * batch as f64 / (active + 1) as f64;
    ReplicaState {
        active_requests: active,
        kv_free_tokens: e.kv().device_free_tokens(),
        kv_capacity_tokens: kv_cap,
        est_request_tds: share,
    }
}

/// What the gateway needs from the serving tier it fronts: a single
/// engine or a whole cluster, driven through one submit/advance API.
pub trait GatewayTarget {
    /// Current simulated time.
    fn now(&self) -> f64;
    /// Per-replica state snapshots for admission control.
    fn replica_states(&self) -> Vec<ReplicaState>;
    /// Submit a request, optionally overriding the routing policy
    /// (single-engine targets ignore the override).
    fn submit_routed(&mut self, spec: RequestSpec, policy: Option<RoutingPolicy>)
        -> Result<()>;
    /// Advance simulated time to `t`, running pending work on the way.
    fn advance_to(&mut self, t: f64) -> Result<()>;
    /// Finish all remaining work and take the per-replica metrics.
    fn drain(&mut self) -> Result<Vec<Metrics>>;
}

impl GatewayTarget for Engine<SimBackend, VirtualClock> {
    fn now(&self) -> f64 {
        self.clock().now()
    }

    fn replica_states(&self) -> Vec<ReplicaState> {
        vec![engine_state(self)]
    }

    fn submit_routed(
        &mut self,
        spec: RequestSpec,
        _policy: Option<RoutingPolicy>,
    ) -> Result<()> {
        self.submit(spec).map(|_| ())
    }

    fn advance_to(&mut self, t: f64) -> Result<()> {
        while self.has_work() && self.clock().now() < t {
            self.tick()?;
        }
        self.advance_clock_to(t);
        Ok(())
    }

    fn drain(&mut self) -> Result<Vec<Metrics>> {
        while self.has_work() {
            self.tick()?;
        }
        Ok(vec![std::mem::take(self.metrics_mut())])
    }
}

impl GatewayTarget for Cluster {
    fn now(&self) -> f64 {
        Cluster::now(self)
    }

    fn replica_states(&self) -> Vec<ReplicaState> {
        self.replicas().iter().map(engine_state).collect()
    }

    fn submit_routed(
        &mut self,
        spec: RequestSpec,
        policy: Option<RoutingPolicy>,
    ) -> Result<()> {
        self.submit_with_policy(spec, policy).map(|_| ())
    }

    fn advance_to(&mut self, t: f64) -> Result<()> {
        self.advance_all_to(t)
    }

    fn drain(&mut self) -> Result<Vec<Metrics>> {
        Cluster::drain(self)
    }
}

/// Outcome of one gateway submission.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum SubmitOutcome {
    Admitted,
    Deferred,
    Rejected(RejectReason),
}

/// A rejected request with its structured reason.
#[derive(Debug, Clone)]
pub struct Rejection {
    pub id: usize,
    pub time: f64,
    pub reason: RejectReason,
}

/// Lifetime counters.
#[derive(Debug, Clone, Default)]
pub struct GatewayStats {
    pub arrivals: usize,
    pub admitted: usize,
    /// Requests that passed through the defer queue (admitted or not).
    pub deferred: usize,
    pub rejected: usize,
    pub surge_transitions: u64,
}

/// One served request's delivery-layer outcome.
#[derive(Debug, Clone)]
pub struct ServedRequest {
    pub id: usize,
    /// Final QoE with unshaped (as-generated) delivery.
    pub raw_qoe: f64,
    /// Final QoE after the gateway pacer shapes delivery (== raw when
    /// pacing is disabled).
    pub paced_qoe: f64,
    /// Tokens delivered while the client buffer already held undigested
    /// tokens (ahead of the digestion deadline), unshaped delivery.
    pub raw_early_tokens: usize,
    /// Same, for the shaped delivery the client actually sees.
    pub paced_early_tokens: usize,
    pub output_tokens: usize,
}

/// Result of a full gateway trace run.
#[derive(Debug)]
pub struct GatewayRunResult {
    pub per_replica: Vec<Metrics>,
    pub served: Vec<ServedRequest>,
    pub rejections: Vec<Rejection>,
    pub stats: GatewayStats,
}

impl GatewayRunResult {
    /// Mean final QoE over served requests (post-pacing).
    pub fn mean_served_qoe(&self) -> f64 {
        if self.served.is_empty() {
            return 0.0;
        }
        self.served.iter().map(|s| s.paced_qoe).sum::<f64>() / self.served.len() as f64
    }

    /// Mean QoE over *all* arrivals, counting each rejection as QoE 0.
    pub fn mean_qoe_incl_rejects(&self) -> f64 {
        let n = self.served.len() + self.rejections.len();
        if n == 0 {
            return 0.0;
        }
        self.served.iter().map(|s| s.paced_qoe).sum::<f64>() / n as f64
    }

    pub fn rejected_fraction(&self) -> f64 {
        let n = self.served.len() + self.rejections.len();
        if n == 0 {
            return 0.0;
        }
        self.rejections.len() as f64 / n as f64
    }

    /// (unshaped, shaped) fraction of tokens delivered ahead of the
    /// digestion deadline.
    pub fn early_token_fractions(&self) -> (f64, f64) {
        let total: usize = self.served.iter().map(|s| s.output_tokens).sum();
        if total == 0 {
            return (0.0, 0.0);
        }
        let raw: usize = self.served.iter().map(|s| s.raw_early_tokens).sum();
        let paced: usize = self.served.iter().map(|s| s.paced_early_tokens).sum();
        (raw as f64 / total as f64, paced as f64 / total as f64)
    }
}

/// Count tokens delivered while the client buffer already held at least
/// one undigested token — delivery ahead of the digestion deadline.
/// `times` are request-relative delivery timestamps, non-decreasing.
pub fn count_early_tokens(spec: &QoeSpec, times: &[f64]) -> usize {
    let mut st = DigestState::new(spec);
    let mut early = 0;
    for &t in times {
        st.advance_to(t);
        if st.buffered() >= 1.0 - 1e-9 {
            early += 1;
        }
        st.deliver(t);
    }
    early
}

/// Evaluate one finished request's delivery-layer outcome, optionally
/// re-shaping its token timeline through the pacer.
fn served_outcome(r: &RequestRecord, pacing_enabled: bool, cfg: &PacingConfig) -> ServedRequest {
    let spec = QoeSpec::new(r.expected_ttft.max(0.0), r.expected_tds.max(0.1));
    let rel: Vec<f64> = r.token_times.iter().map(|t| (t - r.arrival).max(0.0)).collect();
    let raw_early = count_early_tokens(&spec, &rel);
    if !pacing_enabled {
        return ServedRequest {
            id: r.id,
            raw_qoe: r.final_qoe,
            paced_qoe: r.final_qoe,
            raw_early_tokens: raw_early,
            paced_early_tokens: raw_early,
            output_tokens: r.output_tokens,
        };
    }
    let paced = pace_times(&spec, cfg, &rel);
    let mut st = DigestState::new(&spec);
    for &t in &paced {
        st.deliver(t);
    }
    let paced_qoe = qoe_finished(&spec, &st, paced.len());
    let paced_early = count_early_tokens(&spec, &paced);
    ServedRequest {
        id: r.id,
        raw_qoe: r.final_qoe,
        paced_qoe,
        raw_early_tokens: raw_early,
        paced_early_tokens: paced_early,
        output_tokens: r.output_tokens,
    }
}

struct DeferredRequest {
    spec: RequestSpec,
    enqueued_at: f64,
}

/// The gateway orchestrator.
pub struct Gateway<T: GatewayTarget> {
    cfg: GatewayConfig,
    target: T,
    admission: AdmissionController,
    surge: SurgeDetector,
    queue: VecDeque<DeferredRequest>,
    rejections: Vec<Rejection>,
    stats: GatewayStats,
}

impl<T: GatewayTarget> Gateway<T> {
    pub fn new(target: T, cfg: GatewayConfig) -> Self {
        let admission = AdmissionController::new(cfg.admission.clone());
        let surge = SurgeDetector::new(cfg.surge.clone());
        Gateway {
            cfg,
            target,
            admission,
            surge,
            queue: VecDeque::new(),
            rejections: Vec::new(),
            stats: GatewayStats::default(),
        }
    }

    pub fn target(&self) -> &T {
        &self.target
    }

    pub fn stats(&self) -> &GatewayStats {
        &self.stats
    }

    pub fn rejections(&self) -> &[Rejection] {
        &self.rejections
    }

    pub fn mode(&self) -> LoadMode {
        self.surge.mode()
    }

    /// Handle one arriving request at its arrival time: advance the
    /// serving tier, update the surge estimate, retry the defer queue,
    /// then admit/defer/reject the newcomer.
    pub fn submit(&mut self, spec: RequestSpec) -> Result<SubmitOutcome> {
        let t = spec.arrival;
        self.target.advance_to(t)?;
        self.surge.observe(t);
        self.flush_deferred(t)?;
        self.stats.arrivals += 1;
        if !self.cfg.admission_enabled {
            self.route(spec)?;
            self.stats.admitted += 1;
            return Ok(SubmitOutcome::Admitted);
        }
        let states = self.target.replica_states();
        let decision = self.admission.decide(
            spec.prompt_tokens,
            &spec.qoe,
            &states,
            self.surge.mode(),
            self.queue.len(),
        );
        match decision {
            AdmissionDecision::Admit => {
                self.route(spec)?;
                self.stats.admitted += 1;
                Ok(SubmitOutcome::Admitted)
            }
            AdmissionDecision::Defer => {
                self.queue.push_back(DeferredRequest { spec, enqueued_at: t });
                self.stats.deferred += 1;
                Ok(SubmitOutcome::Deferred)
            }
            AdmissionDecision::Reject(reason) => {
                self.reject(spec.id, t, reason);
                Ok(SubmitOutcome::Rejected(reason))
            }
        }
    }

    fn route(&mut self, spec: RequestSpec) -> Result<()> {
        // Surge-aware routing is part of the admission-control response;
        // with admission disabled the gateway must be routing-transparent
        // (it is the experiment's no-gateway baseline).
        let policy = if self.cfg.admission_enabled && self.surge.mode() == LoadMode::Surge {
            self.cfg.surge_routing
        } else {
            None
        };
        self.target.submit_routed(spec, policy)
    }

    fn reject(&mut self, id: usize, time: f64, reason: RejectReason) {
        self.rejections.push(Rejection { id, time, reason });
        self.stats.rejected += 1;
    }

    /// Re-examine the defer queue (FIFO) at time `t`: admit what now
    /// fits, expire what has waited too long, stop at the first request
    /// that must keep waiting (order preserved).
    fn flush_deferred(&mut self, t: f64) -> Result<()> {
        loop {
            let (id, prompt, qoe, enqueued_at) = match self.queue.front() {
                Some(d) => (d.spec.id, d.spec.prompt_tokens, d.spec.qoe, d.enqueued_at),
                None => return Ok(()),
            };
            let waited = t - enqueued_at;
            if waited > self.cfg.admission.max_defer_wait {
                self.queue.pop_front();
                self.reject(id, t, RejectReason::DeferTimeout { waited });
                continue;
            }
            let states = self.target.replica_states();
            let depth = self.queue.len().saturating_sub(1);
            let decision =
                self.admission.decide(prompt, &qoe, &states, self.surge.mode(), depth);
            match decision {
                AdmissionDecision::Admit => {
                    let d = self.queue.pop_front().unwrap();
                    self.route(d.spec)?;
                    self.stats.admitted += 1;
                }
                _ => return Ok(()),
            }
        }
    }

    /// Drain the serving tier, giving the defer queue its bounded chance
    /// to be admitted as capacity frees, then post-process delivery.
    pub fn finish(&mut self) -> Result<GatewayRunResult> {
        // Step simulated time forward until the queue resolves: each
        // entry either admits or hits its defer timeout.
        while !self.queue.is_empty() {
            let t = self.target.now() + 0.25;
            self.target.advance_to(t)?;
            self.flush_deferred(t)?;
        }
        let per_replica = self.target.drain()?;
        self.stats.surge_transitions = self.surge.transitions();
        let mut served = Vec::new();
        for m in &per_replica {
            for r in &m.requests {
                served.push(served_outcome(r, self.cfg.pacing_enabled, &self.cfg.pacing));
            }
        }
        Ok(GatewayRunResult {
            per_replica,
            served,
            rejections: self.rejections.clone(),
            stats: self.stats.clone(),
        })
    }

    /// Run a whole trace through the gateway and finish.
    pub fn run_trace(&mut self, mut trace: Vec<RequestSpec>) -> Result<GatewayRunResult> {
        trace.sort_by(|a, b| a.arrival.partial_cmp(&b.arrival).unwrap());
        for spec in trace {
            self.submit(spec)?;
        }
        self.finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SchedulerConfig;
    use crate::coordinator::engine::EngineConfig;
    use crate::coordinator::sched::fcfs::FcfsScheduler;
    use crate::model::gpu::a100_4x;
    use crate::model::latency::LatencyModel;
    use crate::model::llm::opt_66b;
    use crate::util::stats::mean;
    use crate::workload::{ArrivalProcess, Dataset, QoeTrace, Workload};

    fn sim_engine(kv_tokens: usize) -> Engine<SimBackend, VirtualClock> {
        let latency = LatencyModel::for_deployment(&opt_66b(), &a100_4x());
        let cfg = EngineConfig {
            kv_capacity_tokens: kv_tokens,
            swap_capacity_tokens: kv_tokens * 2,
            ..EngineConfig::default()
        };
        Engine::new(
            cfg,
            SimBackend::new(latency.clone()),
            VirtualClock::default(),
            Box::new(FcfsScheduler::new()),
            latency,
        )
    }

    fn trace(n: usize, rate: f64, seed: u64) -> Vec<RequestSpec> {
        Workload {
            dataset: Dataset::ShareGpt,
            arrivals: ArrivalProcess::Poisson { rate },
            qoe_trace: QoeTrace::TextReading,
            num_requests: n,
            seed,
        }
        .generate()
    }

    fn disabled_cfg() -> GatewayConfig {
        GatewayConfig {
            admission_enabled: false,
            pacing_enabled: false,
            ..GatewayConfig::default()
        }
    }

    #[test]
    fn disabled_gateway_is_transparent() {
        // With admission and pacing off, the gateway must reproduce a
        // plain engine run exactly.
        let reqs = trace(40, 2.0, 11);
        let mut plain = sim_engine(100_000);
        plain.load_trace(reqs.clone());
        let plain_qoe = plain.run_to_completion().unwrap().avg_qoe();

        let mut gw = Gateway::new(sim_engine(100_000), disabled_cfg());
        let res = gw.run_trace(reqs).unwrap();
        assert_eq!(res.served.len(), 40);
        assert!(res.rejections.is_empty());
        let gw_qoe = mean(&res.served.iter().map(|s| s.paced_qoe).collect::<Vec<_>>());
        assert!((gw_qoe - plain_qoe).abs() < 1e-9, "gateway {gw_qoe} vs plain {plain_qoe}");
    }

    #[test]
    fn overload_sheds_and_protects_served_qoe() {
        // Far past capacity, the full gateway must reject some requests
        // and serve the admitted ones at better QoE than the unprotected
        // engine's average.
        let reqs = trace(120, 12.0, 7);
        let mut plain = sim_engine(2500);
        plain.load_trace(reqs.clone());
        let baseline = plain.run_to_completion().unwrap().avg_qoe();

        let mut cfg = GatewayConfig::default();
        cfg.surge.baseline_rate = 1.5;
        let mut gw = Gateway::new(sim_engine(2500), cfg);
        let res = gw.run_trace(reqs).unwrap();
        assert!(res.stats.rejected > 0, "no rejections under 8× overload");
        assert_eq!(res.served.len() + res.rejections.len(), 120, "request conservation");
        assert!(
            res.mean_served_qoe() > baseline,
            "served QoE {:.3} must beat unprotected {:.3}",
            res.mean_served_qoe(),
            baseline
        );
    }

    #[test]
    fn deferred_request_is_served_when_capacity_frees() {
        // Normal mode, a request that does not fit defers, then admits
        // once the running request finishes.
        let mut cfg = GatewayConfig::default();
        cfg.admission.max_defer_wait = 60.0;
        cfg.pacing_enabled = false;
        let mut gw = Gateway::new(sim_engine(2000), cfg);
        let mk = |id: usize, arrival: f64, prompt: usize| RequestSpec {
            id,
            arrival,
            prompt_tokens: prompt,
            output_tokens: 40,
            qoe: QoeSpec::new(1.0, 4.8),
        };
        assert_eq!(gw.submit(mk(0, 0.5, 1500)).unwrap(), SubmitOutcome::Admitted);
        assert_eq!(gw.submit(mk(1, 1.0, 1200)).unwrap(), SubmitOutcome::Deferred);
        let res = gw.finish().unwrap();
        assert_eq!(res.served.len(), 2, "deferred request must eventually serve");
        assert!(res.rejections.is_empty());
        assert_eq!(res.stats.deferred, 1);
        // The deferred request's wait is charged to its QoE (arrival
        // timestamp preserved): its QoE must trail the first request's.
        let q0 = res.served.iter().find(|s| s.id == 0).unwrap().raw_qoe;
        let q1 = res.served.iter().find(|s| s.id == 1).unwrap().raw_qoe;
        assert!(q1 < q0, "deferral must cost QoE: {q1} !< {q0}");
    }

    #[test]
    fn pacing_reduces_early_tokens_without_qoe_loss() {
        let mut cfg = GatewayConfig::default();
        cfg.admission_enabled = false;
        cfg.pacing_enabled = true;
        let mut gw = Gateway::new(sim_engine(100_000), cfg);
        // Light load → heavy overfast generation.
        let res = gw.run_trace(trace(30, 0.5, 3)).unwrap();
        let (raw, paced) = res.early_token_fractions();
        assert!(raw > 0.2, "light load should generate ahead of digestion ({raw})");
        assert!(paced < raw, "pacing must reduce early tokens ({paced} !< {raw})");
        for s in &res.served {
            assert!(
                s.paced_qoe >= s.raw_qoe - 1e-6,
                "pacing lowered QoE on {}: {} < {}",
                s.id,
                s.paced_qoe,
                s.raw_qoe
            );
        }
    }

    #[test]
    fn cluster_target_routes_and_completes() {
        let latency = LatencyModel::for_deployment(&opt_66b(), &a100_4x());
        let ecfg = EngineConfig {
            kv_capacity_tokens: 8000,
            swap_capacity_tokens: 16_000,
            ..EngineConfig::default()
        };
        let cluster = Cluster::new(
            3,
            ecfg,
            latency,
            &SchedulerConfig::Fcfs,
            RoutingPolicy::QoeAware,
        );
        let mut gw = Gateway::new(cluster, disabled_cfg());
        let res = gw.run_trace(trace(60, 3.0, 5)).unwrap();
        assert_eq!(res.served.len(), 60);
        assert_eq!(res.per_replica.len(), 3);
        let total: usize = res.per_replica.iter().map(|m| m.requests.len()).sum();
        assert_eq!(total, 60);
    }

    #[test]
    fn early_token_counter_matches_intuition() {
        let spec = QoeSpec::new(1.0, 2.0);
        // Burst of 5 at t=1: the first displays immediately, 4 are early.
        assert_eq!(count_early_tokens(&spec, &[1.0, 1.0, 1.0, 1.0, 1.0]), 4);
        // Exactly paced delivery: never early.
        let paced: Vec<f64> = (0..6).map(|i| 1.0 + i as f64 / 2.0).collect();
        assert_eq!(count_early_tokens(&spec, &paced), 0);
    }
}
