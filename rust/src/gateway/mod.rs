//! The QoE-aware serving gateway — the system's front door.
//!
//! The paper optimizes QoE *inside* one engine and explicitly scopes
//! out the front-end ("cluster-level load balancing ... done
//! separately", §5). This subsystem builds that front end, because QoE
//! is also won or lost before a request ever reaches a scheduler:
//!
//! - [`admission`] — estimate each arriving request's expected QoE gain
//!   and marginal resource cost and admit, defer, or reject it with a
//!   structured reason;
//! - [`pacing`] — shape token delivery at each request's digestion
//!   speed (plus a lead buffer), so the overfast surplus becomes
//!   scheduler slack instead of unread tokens on the wire;
//! - [`surge`] — a windowed arrival-rate detector that switches the
//!   gateway between its permissive normal mode and load-shedding
//!   surge mode (with hysteresis);
//! - [`autoscale`] — a predictive autoscaler that turns the surge
//!   detector's rate estimate (plus KV pressure) into a target replica
//!   count, with cold-start lead time and scale-in hysteresis;
//! - [`federation`] — N gateway instances fronting one cluster, each
//!   deciding admission on a local ledger merged into periodically
//!   exchanged state snapshots (bounded staleness, no central lock);
//! - [`Gateway`] — the orchestrator, wrapping either a single simulated
//!   [`Engine`] or a [`Cluster`] behind one submit/advance API, with
//!   surge-aware routing-policy override for clusters, elastic scaling,
//!   and an optional **spill tier**: a second (cheaper) cluster that
//!   replays requests the primary tier rejected, with the spill wait
//!   charged to the request's original arrival so QoE stays honest.
//!
//! The gateway steps time by *events*: defer-queue deadlines and
//! autoscaler events are swept when they fall due, not when the next
//! request happens to arrive. The live TCP server ([`crate::server`])
//! reuses the same components (admission controller, surge detector,
//! per-request pacers) around its real-model engine.
//!
//! ```
//! use andes::backend::sim::SimBackend;
//! use andes::backend::VirtualClock;
//! use andes::coordinator::engine::{Engine, EngineConfig};
//! use andes::coordinator::sched::fcfs::FcfsScheduler;
//! use andes::gateway::{Gateway, GatewayConfig};
//! use andes::model::gpu::a100_4x;
//! use andes::model::latency::LatencyModel;
//! use andes::model::llm::opt_66b;
//! use andes::qoe::spec::QoeSpec;
//! use andes::workload::RequestSpec;
//!
//! let latency = LatencyModel::for_deployment(&opt_66b(), &a100_4x());
//! let engine = Engine::new(
//!     EngineConfig::default(),
//!     SimBackend::new(latency.clone()),
//!     VirtualClock::default(),
//!     Box::new(FcfsScheduler::new()),
//!     latency,
//! );
//! let mut gw = Gateway::new(engine, GatewayConfig::default());
//! let trace = vec![RequestSpec {
//!     id: 0,
//!     arrival: 0.1,
//!     prompt_tokens: 120,
//!     output_tokens: 30,
//!     qoe: QoeSpec::new(1.0, 4.8),
//!     session: None,
//! }];
//! let res = gw.run_trace(trace).unwrap();
//! assert_eq!(res.served.len(), 1);
//! assert!(res.rejections.is_empty());
//! ```

pub mod admission;
pub mod autoscale;
pub mod federation;
pub mod pacing;
pub mod surge;

pub use admission::{
    AdmissionConfig, AdmissionController, AdmissionDecision, RejectReason, ReplicaState,
    TierWeights,
};
pub use autoscale::{AutoscaleConfig, PredictiveAutoscaler, ScalePlan};
pub use federation::{
    merge_snapshot, FederatedGateway, FederationConfig, FederationRunResult,
    FederationStats, StateSnapshot,
};
pub use pacing::{pace_times, PacingConfig, TokenPacer};
pub use surge::{LoadMode, SurgeConfig, SurgeDetector};

use std::collections::VecDeque;

use anyhow::Result;

use crate::backend::sim::SimBackend;
use crate::backend::{Clock, ExecutionBackend, VirtualClock};
use crate::cluster::{Cluster, RoutingPolicy};
use crate::config::SchedulerConfig;
use crate::coordinator::calendar::{EventCalendar, EventKind, WakeupToken};
use crate::coordinator::engine::{Engine, EngineConfig};
use crate::coordinator::metrics::{Metrics, RequestRecord};
use crate::delivery::{deliver_request, NetworkConfig};
use crate::model::latency::LatencyModel;
use crate::qoe::metric::{qoe_finished, DigestState};
use crate::qoe::spec::QoeSpec;
use crate::telemetry::Telemetry;
use crate::util::json::Json;
use crate::workload::qoe_trace::QoeTrace;
use crate::workload::{RequestSpec, SessionInfo};

/// Gateway configuration.
#[derive(Debug, Clone)]
pub struct GatewayConfig {
    pub admission_enabled: bool,
    pub pacing_enabled: bool,
    pub admission: AdmissionConfig,
    pub pacing: PacingConfig,
    pub surge: SurgeConfig,
    /// Predictive autoscaling of the serving tier (cluster targets
    /// only; disabled by default).
    pub autoscale: AutoscaleConfig,
    /// Routing-policy override while in surge mode (cluster targets
    /// only): spread load instead of QoE-greedy placement.
    pub surge_routing: Option<RoutingPolicy>,
    /// Client-side delivery model (network + playback buffer +
    /// jitter-adaptive pacer lead; DESIGN.md §11). Disabled by default,
    /// which keeps every number bit-identical to the pacer-only path.
    pub network: NetworkConfig,
    /// Compute sweep events from live per-subsystem scans (the
    /// pre-calendar stepping) instead of the event-calendar index.
    /// Proven bit-identical to the calendar path by `tests/calendar.rs`;
    /// kept until the legacy scans are deleted.
    pub legacy_stepping: bool,
}

impl Default for GatewayConfig {
    fn default() -> Self {
        GatewayConfig {
            admission_enabled: true,
            pacing_enabled: true,
            admission: AdmissionConfig::default(),
            pacing: PacingConfig::default(),
            surge: SurgeConfig::default(),
            autoscale: AutoscaleConfig::default(),
            surge_routing: Some(RoutingPolicy::LeastLoaded),
            network: NetworkConfig::default(),
            legacy_stepping: false,
        }
    }
}

impl GatewayConfig {
    /// Derive the engine-side slack-estimator config (DESIGN.md §15)
    /// from this gateway's pacing and delivery settings: the estimator
    /// mirrors the pacer's release rule (or generation times when
    /// pacing is off) and charges the network mix's expected one-way
    /// transit on top (0.0 when the delivery layer is off — the
    /// QoE-spec digestion-rate fallback).
    pub fn slack_config(&self) -> crate::coordinator::SlackConfig {
        crate::coordinator::SlackConfig {
            paced: self.pacing_enabled,
            rate_factor: self.pacing.rate_factor,
            lead_tokens: self.pacing.lead_tokens,
            transit: self.network.expected_transit(),
        }
    }
}

/// Spill (overflow) tier configuration: a second, typically cheaper
/// cluster that replays requests the primary tier rejected
/// (`surge-shed`, `saturated`, `defer-timeout`).
#[derive(Debug, Clone)]
pub struct SpillConfig {
    pub enabled: bool,
    /// Number of spill replicas.
    pub replicas: usize,
    /// KV capacity of each spill replica relative to a primary replica
    /// (the "cheaper hardware" knob); also its cost weight in
    /// cost-weighted replica-seconds.
    pub kv_fraction: f64,
}

impl Default for SpillConfig {
    fn default() -> Self {
        SpillConfig { enabled: false, replicas: 1, kv_fraction: 0.5 }
    }
}

impl SpillConfig {
    /// Build the overflow cluster: `replicas` engines with
    /// `kv_fraction` of the primary KV budget, least-loaded routing
    /// (the spill tier optimizes evacuation, not QoE placement).
    pub fn build_cluster(
        &self,
        engine_cfg: &EngineConfig,
        latency: &LatencyModel,
        scheduler: &SchedulerConfig,
    ) -> Cluster {
        let mut cfg = engine_cfg.clone();
        cfg.kv_capacity_tokens = ((cfg.kv_capacity_tokens as f64 * self.kv_fraction)
            as usize)
            .max(cfg.block_size * 4);
        cfg.swap_capacity_tokens = ((cfg.swap_capacity_tokens as f64 * self.kv_fraction)
            as usize)
            .max(cfg.block_size * 8);
        Cluster::new(
            self.replicas.max(1),
            cfg,
            latency.clone(),
            scheduler,
            RoutingPolicy::LeastLoaded,
        )
    }
}

/// Snapshot one engine's state for admission control. Shared by the sim
/// targets below and the live server's real-model engine.
pub fn engine_state<B: ExecutionBackend, C: Clock>(e: &Engine<B, C>) -> ReplicaState {
    let active = e.active_count();
    let avg_ctx = e.avg_active_context().max(64);
    let kv_cap = e.kv().device_capacity_tokens();
    // Fair-share delivery speed for one more request: the batch is
    // bounded by KV capacity; beyond it, active requests time-share.
    let kv_batch_cap = (kv_cap / avg_ctx).max(1);
    let batch = (active + 1).min(kv_batch_cap);
    let share =
        e.latency().tokens_per_sec(batch, avg_ctx) * batch as f64 / (active + 1) as f64;
    ReplicaState {
        active_requests: active,
        kv_free_tokens: e.kv().device_free_tokens(),
        kv_capacity_tokens: kv_cap,
        est_request_tds: share,
    }
}

/// What the gateway needs from the serving tier it fronts: a single
/// engine or a whole cluster, driven through one submit/advance API.
pub trait GatewayTarget {
    /// Current simulated time.
    fn now(&self) -> f64;
    /// Per-replica state snapshots for admission control.
    fn replica_states(&self) -> Vec<ReplicaState>;
    /// Submit a request, optionally overriding the routing policy
    /// (single-engine targets ignore the override).
    fn submit_routed(&mut self, spec: RequestSpec, policy: Option<RoutingPolicy>)
        -> Result<()>;
    /// Advance simulated time to `t`, running pending work on the way.
    fn advance_to(&mut self, t: f64) -> Result<()>;
    /// Run the target forward past its next internal event (one engine
    /// iteration), returning the new time, or `None` when idle. Lets
    /// the gateway resolve its defer queue event-by-event instead of
    /// guessing a wall step.
    fn step_once(&mut self) -> Result<Option<f64>>;
    /// Finish all remaining work and take the per-replica metrics.
    fn drain(&mut self) -> Result<Vec<Metrics>>;
    /// Number of routable (non-draining) replicas.
    fn routable_replicas(&self) -> usize {
        self.replica_states().len()
    }
    /// Tokens parked for `session_id` on a routable replica (0 when
    /// absent) — drives prefix-aware admission for returning session
    /// turns (DESIGN.md §10).
    fn parked_prefix_tokens(&self, _session_id: u64) -> usize {
        0
    }
    /// Commission one replica at time `t` (elastic clusters only);
    /// returns false when the target cannot scale.
    fn scale_out(&mut self, _t: f64) -> bool {
        false
    }
    /// Begin draining one replica at time `t`; returns false when
    /// nothing can retire.
    fn scale_in(&mut self, _t: f64) -> bool {
        false
    }
    /// Replica-seconds consumed up to `t` — the run's cost metric
    /// (static targets: replica count × elapsed time).
    fn replica_seconds(&self, t: f64) -> f64;
}

impl GatewayTarget for Engine<SimBackend, VirtualClock> {
    fn now(&self) -> f64 {
        self.clock().now()
    }

    fn replica_states(&self) -> Vec<ReplicaState> {
        vec![engine_state(self)]
    }

    fn submit_routed(
        &mut self,
        spec: RequestSpec,
        _policy: Option<RoutingPolicy>,
    ) -> Result<()> {
        self.submit(spec).map(|_| ())
    }

    fn advance_to(&mut self, t: f64) -> Result<()> {
        while self.has_work() && self.clock().now() < t {
            self.tick()?;
        }
        self.advance_clock_to(t);
        Ok(())
    }

    fn step_once(&mut self) -> Result<Option<f64>> {
        if !self.has_work() {
            return Ok(None);
        }
        self.tick()?;
        Ok(Some(self.clock().now()))
    }

    fn drain(&mut self) -> Result<Vec<Metrics>> {
        while self.has_work() {
            self.tick()?;
        }
        Ok(vec![std::mem::take(self.metrics_mut())])
    }

    fn replica_seconds(&self, t: f64) -> f64 {
        // One replica, commissioned at the virtual-time origin.
        t.max(0.0)
    }

    fn parked_prefix_tokens(&self, session_id: u64) -> usize {
        Engine::parked_prefix_tokens(self, session_id)
    }
}

impl GatewayTarget for Cluster {
    fn now(&self) -> f64 {
        Cluster::now(self)
    }

    fn replica_states(&self) -> Vec<ReplicaState> {
        // Draining replicas take no new work, so admission must not
        // count their headroom.
        self.replicas()
            .iter()
            .enumerate()
            .filter(|(i, _)| !self.is_draining(*i))
            .map(|(_, e)| engine_state(e))
            .collect()
    }

    fn submit_routed(
        &mut self,
        spec: RequestSpec,
        policy: Option<RoutingPolicy>,
    ) -> Result<()> {
        self.submit_with_policy(spec, policy).map(|_| ())
    }

    fn advance_to(&mut self, t: f64) -> Result<()> {
        self.advance_all_to(t)
    }

    fn step_once(&mut self) -> Result<Option<f64>> {
        Cluster::step_once(self)
    }

    fn drain(&mut self) -> Result<Vec<Metrics>> {
        Cluster::drain(self)
    }

    fn routable_replicas(&self) -> usize {
        self.routable_count()
    }

    fn scale_out(&mut self, t: f64) -> bool {
        self.add_replica(t);
        true
    }

    fn scale_in(&mut self, t: f64) -> bool {
        self.retire_least_loaded(t).is_some()
    }

    fn replica_seconds(&self, t: f64) -> f64 {
        Cluster::replica_seconds(self, t)
    }

    fn parked_prefix_tokens(&self, session_id: u64) -> usize {
        // Admission may only count a prefix the router will actually
        // reach: with affinity on, the returning turn is pinned to the
        // parking replica; without it, only a single routable replica
        // guarantees the route, and scoring an unreachable prefix would
        // admit marginal turns on a TTFT win that never materializes.
        if !self.session_affinity() && self.routable_count() > 1 {
            return 0;
        }
        self.parked_replica(session_id)
            .map(|i| self.replicas()[i].parked_prefix_tokens(session_id))
            .unwrap_or(0)
    }
}

/// Outcome of one gateway submission.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum SubmitOutcome {
    Admitted,
    Deferred,
    /// Rejected by the primary tier but replayed on the spill tier.
    Spilled(RejectReason),
    Rejected(RejectReason),
}

/// A rejected request with its structured reason.
#[derive(Debug, Clone)]
pub struct Rejection {
    pub id: usize,
    pub time: f64,
    pub reason: RejectReason,
}

/// Lifetime counters.
#[derive(Debug, Clone, Default)]
pub struct GatewayStats {
    pub arrivals: usize,
    pub admitted: usize,
    /// Requests that passed through the defer queue (admitted or not).
    pub deferred: usize,
    pub rejected: usize,
    /// Primary-tier rejections replayed on the spill tier instead of
    /// being dropped.
    pub spilled: usize,
    pub surge_transitions: u64,
    /// Autoscaler activity over the run (includes aborted cold starts).
    pub scale_out_requests: u64,
    pub scale_ins: u64,
}

/// One served request's delivery-layer outcome.
#[derive(Debug, Clone)]
pub struct ServedRequest {
    pub id: usize,
    /// Final QoE with unshaped (as-generated) delivery.
    pub raw_qoe: f64,
    /// Final QoE after the gateway pacer shapes delivery (== raw when
    /// pacing is disabled).
    pub paced_qoe: f64,
    /// Final QoE computed from *client-perceived* arrival times, after
    /// the last-mile network and playback buffer ([`crate::delivery`]).
    /// Equals `paced_qoe` when the delivery model is disabled.
    pub client_qoe: f64,
    /// Client playback stalls (late arrivals past the digestion ramp);
    /// 0 when the delivery model is disabled.
    pub stall_count: usize,
    /// Total seconds the client playback stalled.
    pub stall_time: f64,
    /// Token retransmissions on this request's link.
    pub retransmits: usize,
    /// Tokens that waited out a disconnect episode.
    pub disconnects: usize,
    /// Tokens delivered while the client buffer already held undigested
    /// tokens (ahead of the digestion deadline), unshaped delivery.
    pub raw_early_tokens: usize,
    /// Same, for the shaped delivery the client actually sees.
    pub paced_early_tokens: usize,
    pub output_tokens: usize,
    /// Expected TDS of the request's QoE spec — lets per-tier reporting
    /// classify served requests (engine record ids follow submission
    /// order, not trace order, once a defer queue reorders admissions).
    pub expected_tds: f64,
}

/// Result of a full gateway trace run.
#[derive(Debug)]
pub struct GatewayRunResult {
    pub per_replica: Vec<Metrics>,
    pub served: Vec<ServedRequest>,
    /// Requests the primary tier rejected that were replayed — and
    /// ultimately served — on the spill tier.
    pub spilled: Vec<ServedRequest>,
    pub spill_per_replica: Vec<Metrics>,
    pub rejections: Vec<Rejection>,
    pub stats: GatewayStats,
    /// Primary-tier replica-seconds consumed (commission through
    /// decommission, or run end), the run's cost metric.
    pub replica_seconds: f64,
    /// Spill-tier replica-seconds (unweighted).
    pub spill_replica_seconds: f64,
}

impl GatewayRunResult {
    fn served_qoe_sum(&self) -> f64 {
        self.served.iter().chain(&self.spilled).map(|s| s.paced_qoe).sum::<f64>()
    }

    /// Count of requests that received service (either tier).
    pub fn served_count(&self) -> usize {
        self.served.len() + self.spilled.len()
    }

    /// Mean final QoE over served requests on either tier (post-pacing).
    pub fn mean_served_qoe(&self) -> f64 {
        if self.served_count() == 0 {
            return 0.0;
        }
        self.served_qoe_sum() / self.served_count() as f64
    }

    /// Mean QoE over *all* arrivals, counting each rejection as QoE 0.
    pub fn mean_qoe_incl_rejects(&self) -> f64 {
        let n = self.served_count() + self.rejections.len();
        if n == 0 {
            return 0.0;
        }
        self.served_qoe_sum() / n as f64
    }

    pub fn rejected_fraction(&self) -> f64 {
        let n = self.served_count() + self.rejections.len();
        if n == 0 {
            return 0.0;
        }
        self.rejections.len() as f64 / n as f64
    }

    /// Primary plus spill replica-seconds (unweighted).
    pub fn total_replica_seconds(&self) -> f64 {
        self.replica_seconds + self.spill_replica_seconds
    }

    /// Mean final QoE computed from client-perceived arrival times,
    /// over served requests on either tier (== [`Self::mean_served_qoe`]
    /// when the delivery model is disabled).
    pub fn mean_client_qoe(&self) -> f64 {
        if self.served_count() == 0 {
            return 0.0;
        }
        let sum: f64 =
            self.served.iter().chain(&self.spilled).map(|s| s.client_qoe).sum();
        sum / self.served_count() as f64
    }

    /// The client-vs-server QoE gap: mean server-side (paced) QoE minus
    /// mean client-perceived QoE. 0 with the delivery model disabled;
    /// grows with network quality loss.
    pub fn client_qoe_gap(&self) -> f64 {
        if self.served_count() == 0 {
            return 0.0;
        }
        self.mean_served_qoe() - self.mean_client_qoe()
    }

    /// Total client playback stalls over both tiers.
    pub fn total_stalls(&self) -> usize {
        self.served.iter().chain(&self.spilled).map(|s| s.stall_count).sum()
    }

    /// Total seconds of client playback stall over both tiers.
    pub fn total_stall_time(&self) -> f64 {
        self.served.iter().chain(&self.spilled).map(|s| s.stall_time).sum()
    }

    /// Total token retransmissions over both tiers.
    pub fn total_retransmits(&self) -> usize {
        self.served.iter().chain(&self.spilled).map(|s| s.retransmits).sum()
    }

    /// Total tokens held by disconnect episodes over both tiers.
    pub fn total_disconnects(&self) -> usize {
        self.served.iter().chain(&self.spilled).map(|s| s.disconnects).sum()
    }

    /// (unshaped, shaped) fraction of tokens delivered ahead of the
    /// digestion deadline, over both tiers.
    pub fn early_token_fractions(&self) -> (f64, f64) {
        let all = || self.served.iter().chain(&self.spilled);
        let total: usize = all().map(|s| s.output_tokens).sum();
        if total == 0 {
            return (0.0, 0.0);
        }
        let raw: usize = all().map(|s| s.raw_early_tokens).sum();
        let paced: usize = all().map(|s| s.paced_early_tokens).sum();
        (raw as f64 / total as f64, paced as f64 / total as f64)
    }
}

/// Count tokens delivered while the client buffer already held at least
/// one undigested token — delivery ahead of the digestion deadline.
/// `times` are request-relative delivery timestamps, non-decreasing.
pub fn count_early_tokens(spec: &QoeSpec, times: &[f64]) -> usize {
    let mut st = DigestState::new(spec);
    let mut early = 0;
    for &t in times {
        st.advance_to(t);
        if st.buffered() >= 1.0 - 1e-9 {
            early += 1;
        }
        st.deliver(t);
    }
    early
}

/// Evaluate one finished request's delivery-layer outcome, optionally
/// re-shaping its token timeline through the pacer and carrying it over
/// the simulated last-mile network ([`crate::delivery`]).
fn served_outcome(r: &RequestRecord, cfg: &GatewayConfig) -> ServedRequest {
    let spec = QoeSpec::new(r.expected_ttft.max(0.0), r.expected_tds.max(0.1));
    let rel: Vec<f64> = r.token_times.iter().map(|t| (t - r.arrival).max(0.0)).collect();
    let raw_early = count_early_tokens(&spec, &rel);
    if cfg.network.enabled {
        // Joint pacer → network → client simulation: QoE timestamps come
        // from the client side, and the pacer lead may adapt to jitter.
        let out = deliver_request(
            &spec,
            cfg.pacing_enabled,
            &cfg.pacing,
            &cfg.network,
            r.id,
            &rel,
        );
        let (paced_qoe, paced_early) = if cfg.pacing_enabled {
            let mut st = DigestState::new(&spec);
            for &t in &out.release_times {
                st.deliver(t);
            }
            (
                qoe_finished(&spec, &st, out.release_times.len()),
                count_early_tokens(&spec, &out.release_times),
            )
        } else {
            (r.final_qoe, raw_early)
        };
        return ServedRequest {
            id: r.id,
            raw_qoe: r.final_qoe,
            paced_qoe,
            client_qoe: out.client_qoe,
            stall_count: out.stall_count,
            stall_time: out.stall_time,
            retransmits: out.retransmits,
            disconnects: out.disconnects,
            raw_early_tokens: raw_early,
            paced_early_tokens: paced_early,
            output_tokens: r.output_tokens,
            expected_tds: r.expected_tds,
        };
    }
    if !cfg.pacing_enabled {
        return ServedRequest {
            id: r.id,
            raw_qoe: r.final_qoe,
            paced_qoe: r.final_qoe,
            client_qoe: r.final_qoe,
            stall_count: 0,
            stall_time: 0.0,
            retransmits: 0,
            disconnects: 0,
            raw_early_tokens: raw_early,
            paced_early_tokens: raw_early,
            output_tokens: r.output_tokens,
            expected_tds: r.expected_tds,
        };
    }
    let paced = pace_times(&spec, &cfg.pacing, &rel);
    let mut st = DigestState::new(&spec);
    for &t in &paced {
        st.deliver(t);
    }
    let paced_qoe = qoe_finished(&spec, &st, paced.len());
    let paced_early = count_early_tokens(&spec, &paced);
    ServedRequest {
        id: r.id,
        raw_qoe: r.final_qoe,
        paced_qoe,
        client_qoe: paced_qoe,
        stall_count: 0,
        stall_time: 0.0,
        retransmits: 0,
        disconnects: 0,
        raw_early_tokens: raw_early,
        paced_early_tokens: paced_early,
        output_tokens: r.output_tokens,
        expected_tds: r.expected_tds,
    }
}

struct DeferredRequest {
    spec: RequestSpec,
    enqueued_at: f64,
    /// Tier weight at enqueue time — the defer queue is kept ordered by
    /// weight (descending), FIFO within a tier, so premium requests
    /// re-attempt admission first. Uniform weights degrade to plain
    /// FIFO.
    weight: f64,
    /// Calendar wakeup for this request's admission deadline (None on
    /// the legacy stepping path). Cancelled when the request leaves the
    /// queue for any reason, so the calendar never carries a stale
    /// deadline.
    wakeup: Option<WakeupToken>,
}

/// Insert into a weight-ordered defer queue: descending weight, FIFO
/// within equal weights (skip everything with weight ≥ the newcomer's).
fn enqueue_by_weight(queue: &mut VecDeque<DeferredRequest>, d: DeferredRequest) {
    let pos = queue.iter().position(|q| q.weight < d.weight).unwrap_or(queue.len());
    queue.insert(pos, d);
}

/// Earliest defer deadline in a (weight-ordered) queue.
fn earliest_deadline(queue: &VecDeque<DeferredRequest>, max_wait: f64) -> Option<f64> {
    queue
        .iter()
        .map(|d| d.enqueued_at + max_wait)
        .min_by(f64::total_cmp)
}

/// The gateway orchestrator.
pub struct Gateway<T: GatewayTarget> {
    cfg: GatewayConfig,
    target: T,
    admission: AdmissionController,
    surge: SurgeDetector,
    autoscaler: PredictiveAutoscaler,
    /// Set when the target refused a scale-out (single-engine targets):
    /// stops the planner from re-requesting phantom replicas forever.
    autoscale_unsupported: bool,
    /// The overflow cluster replaying primary rejections, if any.
    spill: Option<Cluster>,
    queue: VecDeque<DeferredRequest>,
    /// Event-time index (DESIGN.md §14): one DeferDeadline wakeup per
    /// queued request plus at most one AutoscaleTick wakeup mirroring
    /// the planner's `next_event()`. Unused on the legacy path.
    calendar: EventCalendar,
    /// Token for the single registered AutoscaleTick wakeup, if any.
    autoscale_wakeup: Option<WakeupToken>,
    rejections: Vec<Rejection>,
    stats: GatewayStats,
    /// Observation handle (defaults to the disabled no-op handle, which
    /// keeps every path bit-identical to the pre-telemetry gateway).
    telemetry: Telemetry,
}

impl<T: GatewayTarget> Gateway<T> {
    pub fn new(target: T, cfg: GatewayConfig) -> Self {
        let admission = AdmissionController::new(cfg.admission.clone());
        let surge = SurgeDetector::new(cfg.surge.clone());
        let autoscaler = PredictiveAutoscaler::new(cfg.autoscale.clone());
        Gateway {
            cfg,
            target,
            admission,
            surge,
            autoscaler,
            autoscale_unsupported: false,
            spill: None,
            queue: VecDeque::new(),
            calendar: EventCalendar::new(),
            autoscale_wakeup: None,
            rejections: Vec::new(),
            stats: GatewayStats::default(),
            telemetry: Telemetry::disabled(),
        }
    }

    /// The autoscaling planner (read-only; drives the drift regression
    /// test in `tests/calendar.rs`).
    pub fn autoscaler(&self) -> &PredictiveAutoscaler {
        &self.autoscaler
    }

    /// Attach a telemetry handle. The gateway records admission
    /// decisions (counters + per-request trace events), defer-queue
    /// depth, surge mode, and — at drain time — per-request TTFT/TPOT/
    /// QoE histograms and delivery counters, all labeled by price tier.
    pub fn set_telemetry(&mut self, tel: Telemetry) {
        self.telemetry = tel;
    }

    pub fn telemetry(&self) -> &Telemetry {
        &self.telemetry
    }

    /// Build a gateway with an overflow cluster that replays primary
    /// rejections (`surge-shed` / `saturated` / `defer-timeout`).
    pub fn with_spill(target: T, cfg: GatewayConfig, spill: Cluster) -> Self {
        let mut gw = Gateway::new(target, cfg);
        gw.spill = Some(spill);
        gw
    }

    pub fn has_spill(&self) -> bool {
        self.spill.is_some()
    }

    pub fn target(&self) -> &T {
        &self.target
    }

    pub fn stats(&self) -> &GatewayStats {
        &self.stats
    }

    pub fn rejections(&self) -> &[Rejection] {
        &self.rejections
    }

    pub fn mode(&self) -> LoadMode {
        self.surge.mode()
    }

    /// Handle one arriving request at its arrival time: advance the
    /// serving tier (sweeping any defer deadlines that fall before the
    /// arrival), update the surge estimate, retry the defer queue, then
    /// admit/defer/reject the newcomer.
    pub fn submit(&mut self, spec: RequestSpec) -> Result<SubmitOutcome> {
        let t = spec.arrival;
        self.advance_world(t)?;
        self.surge.observe(t);
        self.flush_deferred(t)?;
        self.stats.arrivals += 1;
        let tier = QoeTrace::tier_of(&spec.qoe);
        let id = spec.id as u64;
        self.telemetry.event(
            id,
            "arrival",
            t,
            &[("tier", tier.into()), ("prompt_tokens", Json::from(spec.prompt_tokens as u64))],
        );
        self.telemetry.set_gauge(
            "andes_surge_mode",
            &[],
            if self.surge.mode() == LoadMode::Surge { 1.0 } else { 0.0 },
        );
        if !self.cfg.admission_enabled {
            self.route(spec)?;
            self.stats.admitted += 1;
            self.note_admitted(id, tier, t, None);
            return Ok(SubmitOutcome::Admitted);
        }
        let states = self.target.replica_states();
        let prefix = self.usable_prefix(spec.session);
        let decision = self.admission.decide_with_prefix(
            spec.prompt_tokens,
            prefix,
            &spec.qoe,
            &states,
            self.surge.mode(),
            self.queue.len(),
        );
        match decision {
            AdmissionDecision::Admit => {
                self.route(spec)?;
                self.stats.admitted += 1;
                self.note_admitted(id, tier, t, None);
                Ok(SubmitOutcome::Admitted)
            }
            AdmissionDecision::Defer => {
                let weight = self.cfg.admission.tier_weights.weight_for(&spec.qoe);
                let wakeup = (!self.cfg.legacy_stepping).then(|| {
                    self.calendar.register(
                        t + self.cfg.admission.max_defer_wait,
                        EventKind::DeferDeadline,
                        spec.id as u64,
                    )
                });
                enqueue_by_weight(
                    &mut self.queue,
                    DeferredRequest { spec, enqueued_at: t, weight, wakeup },
                );
                self.stats.deferred += 1;
                self.telemetry.inc(
                    "andes_requests_total",
                    &[("outcome", "deferred"), ("tier", tier)],
                    1.0,
                );
                self.telemetry.event(
                    id,
                    "defer",
                    t,
                    &[("depth", Json::from(self.queue.len() as u64))],
                );
                self.telemetry.set_gauge(
                    "andes_defer_queue_depth",
                    &[],
                    self.queue.len() as f64,
                );
                Ok(SubmitOutcome::Deferred)
            }
            AdmissionDecision::Reject(reason) => self.reject_or_spill(spec, t, reason),
        }
    }

    /// Earliest defer deadline. The queue is ordered by tier weight, so
    /// the earliest enqueue need not be at the front; with uniform
    /// weights the order is FIFO and this is the front's deadline. The
    /// calendar query and the legacy queue scan compute the same value
    /// (`enqueued_at + max_defer_wait`), so the two paths agree bit for
    /// bit.
    fn next_defer_deadline(&self) -> Option<f64> {
        if self.cfg.legacy_stepping {
            earliest_deadline(&self.queue, self.cfg.admission.max_defer_wait)
        } else {
            self.calendar.next_time_of(EventKind::DeferDeadline)
        }
    }

    /// Parked-prefix tokens usable by a request (0 for one-shot
    /// requests, opening turns, and missing/evicted prefixes).
    fn usable_prefix(&self, session: Option<SessionInfo>) -> usize {
        session
            .map(|s| s.usable_prefix(self.target.parked_prefix_tokens(s.session_id)))
            .unwrap_or(0)
    }

    /// Next instant before `t` at which gateway state changes on its
    /// own: a defer deadline falling due, a cold start completing, or
    /// a scale-in hold expiring. On the calendar path the autoscaler's
    /// wakeup is read from the calendar index, which
    /// [`Self::reconcile_autoscale_wakeup`] keeps equal to the
    /// planner's live `next_event()` (planner state only changes inside
    /// `autoscale_step`).
    fn next_sweep_event(&self, t: f64) -> Option<f64> {
        let auto = if !self.cfg.legacy_stepping {
            self.calendar.next_time_of(EventKind::AutoscaleTick)
        } else if self.autoscale_unsupported {
            None
        } else {
            self.autoscaler.next_event()
        };
        let ev = match (self.next_defer_deadline(), auto) {
            (Some(a), Some(b)) => a.min(b),
            (Some(a), None) | (None, Some(a)) => a,
            (None, None) => return None,
        };
        (ev < t).then_some(ev)
    }

    /// Bring auxiliary state up to `t` and re-examine the defer queue:
    /// spill clocks advance, the autoscaler plans (and the plan is
    /// applied), deferred requests admit or expire.
    fn sweep_at(&mut self, t: f64) -> Result<()> {
        self.sync_spill(t)?;
        self.autoscale_step(t);
        self.flush_deferred(t)
    }

    /// Advance the whole world (primary tier, spill tier, autoscaler)
    /// to `t`, sweeping every event that falls inside the gap at its
    /// own due time — a deferred request is re-examined at its
    /// deadline, not at the next arrival (which under sparse traffic
    /// could be far later, inflating `waited` or admitting the request
    /// seconds late), and autoscaler events (cold starts completing,
    /// scale-in holds expiring) fire inside idle gaps instead of
    /// charging replica-seconds until the next arrival.
    fn advance_world(&mut self, t: f64) -> Result<()> {
        let mut last_ev = f64::NEG_INFINITY;
        while let Some(ev) = self.next_sweep_event(t) {
            if ev <= last_ev {
                // Defensive: every sweep must advance time (same-instant
                // defer deadlines are all handled by one flush).
                break;
            }
            last_ev = ev;
            self.target.advance_to(ev)?;
            self.sweep_at(ev)?;
        }
        self.target.advance_to(t)?;
        self.sync_spill(t)?;
        self.autoscale_step(t);
        if self.telemetry.is_enabled() {
            self.telemetry
                .set_gauge("andes_replicas", &[], self.target.routable_replicas() as f64);
            self.telemetry.maybe_snapshot(t);
        }
        Ok(())
    }

    /// Keep the spill tier's clocks in lockstep with the primary tier.
    fn sync_spill(&mut self, t: f64) -> Result<()> {
        if let Some(sp) = self.spill.as_mut() {
            sp.advance_all_to(t)?;
        }
        Ok(())
    }

    /// Run one autoscaler planning step at time `t` and apply the plan.
    fn autoscale_step(&mut self, t: f64) {
        if !self.cfg.autoscale.enabled || self.autoscale_unsupported {
            self.reconcile_autoscale_wakeup();
            return;
        }
        // The planner must never observe time running backwards. A
        // sweep accounted at a defer deadline the serving tier already
        // overshot passes the deadline itself as `t` while the tier
        // clock sits later; evaluating there silently rewound the
        // planner (stale cold-start commissioning, a regressing
        // `last_eval`). Clamp to the tier clock so the defer sweep and
        // the evaluation tick agree on "now" within one advance — the
        // expiry itself stays accounted at the exact deadline by
        // `flush_deferred`.
        let t = t.max(self.target.now());
        let states = self.target.replica_states();
        let live = self.target.routable_replicas();
        let rate = self.surge.rate_at(t);
        let plan = self.autoscaler.evaluate(t, rate, &states, live);
        for _ in 0..plan.commission {
            if !self.target.scale_out(t) {
                // The target cannot scale (e.g. a single engine): stop
                // planning rather than re-request phantom replicas on
                // every cold-start expiry for the rest of the run.
                self.autoscale_unsupported = true;
                break;
            }
        }
        for _ in 0..plan.retire {
            if self.target.routable_replicas() <= self.cfg.autoscale.min_replicas
                || !self.target.scale_in(t)
            {
                break;
            }
        }
        self.reconcile_autoscale_wakeup();
    }

    /// Re-point the calendar's single AutoscaleTick wakeup at the
    /// planner's `next_event()`. Planner state only changes inside
    /// [`Self::autoscale_step`], so reconciling here (on every exit
    /// path) keeps the calendar index exactly equal to the live scan
    /// the legacy path performs.
    fn reconcile_autoscale_wakeup(&mut self) {
        if self.cfg.legacy_stepping {
            return;
        }
        if let Some(w) = self.autoscale_wakeup.take() {
            self.calendar.cancel(w);
        }
        if self.autoscale_unsupported {
            return;
        }
        if let Some(ev) = self.autoscaler.next_event() {
            self.autoscale_wakeup =
                Some(self.calendar.register(ev, EventKind::AutoscaleTick, 0));
        }
    }

    fn route(&mut self, spec: RequestSpec) -> Result<()> {
        // Surge-aware routing is part of the admission-control response;
        // with admission disabled the gateway must be routing-transparent
        // (it is the experiment's no-gateway baseline).
        let policy = if self.cfg.admission_enabled && self.surge.mode() == LoadMode::Surge {
            self.cfg.surge_routing
        } else {
            None
        };
        self.target.submit_routed(spec, policy)
    }

    /// Record one drained request into the registry and tracer:
    /// per-tier TTFT/TPOT/QoE histograms, token and delivery counters,
    /// and the tail of its trace span (first token, summarized pacer
    /// releases, network incidents, finish).
    fn record_served(&self, r: &RequestRecord, s: &ServedRequest, spill: bool) {
        if !self.telemetry.is_enabled() {
            return;
        }
        let tier = QoeTrace::tier_of(&QoeSpec::new(
            r.expected_ttft.max(0.0),
            r.expected_tds.max(0.1),
        ));
        let labels = [("tier", tier)];
        // Span key: the trace-level spec id, not the engine-local record
        // id — routing/defer reordering makes the two diverge.
        let id = r.spec_id as u64;
        if r.ttft.is_finite() && r.ttft >= 0.0 {
            self.telemetry.observe_latency("andes_ttft_seconds", &labels, r.ttft);
            self.telemetry.event(
                id,
                "first_token",
                r.arrival + r.ttft,
                &[("ttft", r.ttft.into())],
            );
        }
        if r.avg_tds.is_finite() && r.avg_tds > 0.0 {
            self.telemetry.observe_tpot("andes_tpot_seconds", &labels, 1.0 / r.avg_tds);
        }
        self.telemetry.observe_unit("andes_qoe", &labels, s.client_qoe.clamp(0.0, 1.0));
        self.telemetry.inc("andes_tokens_total", &labels, s.output_tokens as f64);
        if self.cfg.pacing_enabled {
            // Pacer releases are summarized into one event per stream
            // (one event per token would dominate the ring buffer).
            self.telemetry.event(
                id,
                "pacer_release",
                r.finished_at,
                &[
                    ("tokens", Json::from(s.output_tokens as u64)),
                    ("early_tokens", Json::from(s.paced_early_tokens as u64)),
                ],
            );
            self.telemetry.set_gauge(
                "andes_pacer_lead_tokens",
                &[],
                self.cfg.pacing.lead_tokens as f64,
            );
        }
        if s.stall_count > 0 {
            self.telemetry.inc("andes_net_stalls_total", &labels, s.stall_count as f64);
            self.telemetry.inc("andes_net_stall_seconds_total", &labels, s.stall_time);
            self.telemetry.event(
                id,
                "net_stall",
                r.finished_at,
                &[
                    ("count", Json::from(s.stall_count as u64)),
                    ("seconds", s.stall_time.into()),
                ],
            );
        }
        if s.retransmits > 0 {
            self.telemetry.inc("andes_net_retransmits_total", &labels, s.retransmits as f64);
            self.telemetry.event(
                id,
                "retransmit",
                r.finished_at,
                &[("count", Json::from(s.retransmits as u64))],
            );
        }
        if s.disconnects > 0 {
            self.telemetry.inc("andes_net_disconnects_total", &labels, s.disconnects as f64);
            self.telemetry.event(
                id,
                "disconnect",
                r.finished_at,
                &[("tokens", Json::from(s.disconnects as u64))],
            );
        }
        self.telemetry.event(
            id,
            "finish",
            r.finished_at,
            &[
                ("tokens", Json::from(s.output_tokens as u64)),
                ("qoe", s.client_qoe.into()),
                ("tier", tier.into()),
                ("spill", spill.into()),
            ],
        );
    }

    /// Counter + trace event for an admitted request; `waited` is set
    /// when the request sat in the defer queue first.
    fn note_admitted(&self, id: u64, tier: &str, t: f64, waited: Option<f64>) {
        self.telemetry.inc(
            "andes_requests_total",
            &[("outcome", "admitted"), ("tier", tier)],
            1.0,
        );
        match waited {
            Some(w) => self.telemetry.event(id, "admit", t, &[("waited", w.into())]),
            None => self.telemetry.event(id, "admit", t, &[]),
        }
    }

    /// Drop a rejected request — unless the reason is spill-eligible
    /// and an overflow tier exists, in which case the request is
    /// replayed there. The spec keeps its original arrival timestamp,
    /// so the whole spill wait is charged to the request's QoE.
    fn reject_or_spill(
        &mut self,
        spec: RequestSpec,
        t: f64,
        reason: RejectReason,
    ) -> Result<SubmitOutcome> {
        let spillable = matches!(
            reason,
            RejectReason::SurgeShed { .. }
                | RejectReason::Saturated { .. }
                | RejectReason::DeferTimeout { .. }
        );
        let id = spec.id as u64;
        let tier = QoeTrace::tier_of(&spec.qoe);
        if spillable {
            if let Some(sp) = self.spill.as_mut() {
                // The spill clocks are already at `t`: every caller
                // (submit → advance_world, flush_deferred → sweep_at)
                // runs sync_spill first.
                sp.submit(spec)?;
                self.stats.spilled += 1;
                self.telemetry.inc(
                    "andes_requests_total",
                    &[("outcome", "spilled"), ("tier", tier)],
                    1.0,
                );
                self.telemetry.event(id, "spill", t, &[("cause", reason.label().into())]);
                return Ok(SubmitOutcome::Spilled(reason));
            }
        }
        self.rejections.push(Rejection { id: spec.id, time: t, reason });
        self.stats.rejected += 1;
        self.telemetry.inc(
            "andes_requests_total",
            &[("outcome", "rejected"), ("tier", tier)],
            1.0,
        );
        self.telemetry.inc("andes_rejects_total", &[("cause", reason.label())], 1.0);
        self.telemetry.event(id, "reject", t, &[("cause", reason.label().into())]);
        Ok(SubmitOutcome::Rejected(reason))
    }

    /// Re-examine the defer queue at time `t`. The queue is ordered by
    /// tier weight (FIFO within a tier): the highest-priority request
    /// re-attempts admission first, and admission stops at the first
    /// front that must keep waiting (head-of-line order preserved, as
    /// in the tier-blind FIFO). Requests at their deadline — wherever
    /// they sit in the priority order — get one final admission check
    /// before expiring.
    fn flush_deferred(&mut self, t: f64) -> Result<()> {
        loop {
            let (prompt, qoe, session) = match self.queue.front() {
                Some(d) => (d.spec.prompt_tokens, d.spec.qoe, d.spec.session),
                None => return Ok(()),
            };
            let states = self.target.replica_states();
            let depth = self.queue.len().saturating_sub(1);
            let prefix = self.usable_prefix(session);
            let decision = self
                .admission
                .decide_with_prefix(prompt, prefix, &qoe, &states, self.surge.mode(), depth);
            if decision == AdmissionDecision::Admit {
                // lint:allow(D6, front() returned Some at the top of the loop)
                let d = self.queue.pop_front().unwrap();
                if let Some(w) = d.wakeup {
                    self.calendar.cancel(w);
                }
                let (id, tier, waited) =
                    (d.spec.id as u64, QoeTrace::tier_of(&d.spec.qoe), t - d.enqueued_at);
                self.route(d.spec)?;
                self.stats.admitted += 1;
                self.note_admitted(id, tier, t, Some(waited));
                self.telemetry.set_gauge(
                    "andes_defer_queue_depth",
                    &[],
                    self.queue.len() as f64,
                );
                continue;
            }
            // The front must keep waiting: resolve whatever has reached
            // its deadline. With uniform weights the front is also the
            // oldest entry, so this reduces to the FIFO expiry sweep.
            let due_idx = (0..self.queue.len()).find(|&i| {
                t - self.queue[i].enqueued_at + 1e-9 >= self.cfg.admission.max_defer_wait
            });
            match due_idx {
                Some(0) => {
                    // The admission check above was the front's final
                    // chance (a request that fits *right now* is
                    // admitted rather than rejected on a technicality);
                    // it failed, so the deadline stands.
                    // lint:allow(D6, due_idx == Some(0) proves the queue is non-empty)
                    let d = self.queue.pop_front().unwrap();
                    if let Some(w) = d.wakeup {
                        self.calendar.cancel(w);
                    }
                    let waited = t - d.enqueued_at;
                    self.reject_or_spill(d.spec, t, RejectReason::DeferTimeout { waited })?;
                    self.telemetry.set_gauge(
                        "andes_defer_queue_depth",
                        &[],
                        self.queue.len() as f64,
                    );
                }
                Some(i) => {
                    // A lower-priority request hit its deadline while
                    // the front blocks: its own final admission check.
                    let (p2, q2, s2) = (
                        self.queue[i].spec.prompt_tokens,
                        self.queue[i].spec.qoe,
                        self.queue[i].spec.session,
                    );
                    let states = self.target.replica_states();
                    let prefix2 = self.usable_prefix(s2);
                    let d2 = self.admission.decide_with_prefix(
                        p2,
                        prefix2,
                        &q2,
                        &states,
                        self.surge.mode(),
                        self.queue.len().saturating_sub(1),
                    );
                    // lint:allow(D6, i indexes into the queue per the find() above)
                    let d = self.queue.remove(i).unwrap();
                    if let Some(w) = d.wakeup {
                        self.calendar.cancel(w);
                    }
                    if d2 == AdmissionDecision::Admit {
                        let (id, tier, waited) =
                            (d.spec.id as u64, QoeTrace::tier_of(&d.spec.qoe), t - d.enqueued_at);
                        self.route(d.spec)?;
                        self.stats.admitted += 1;
                        self.note_admitted(id, tier, t, Some(waited));
                    } else {
                        let waited = t - d.enqueued_at;
                        self.reject_or_spill(
                            d.spec,
                            t,
                            RejectReason::DeferTimeout { waited },
                        )?;
                    }
                    self.telemetry.set_gauge(
                        "andes_defer_queue_depth",
                        &[],
                        self.queue.len() as f64,
                    );
                }
                None => return Ok(()),
            }
        }
    }

    /// Drain the serving tier, giving the defer queue its bounded chance
    /// to be admitted as capacity frees, then post-process delivery.
    pub fn finish(&mut self) -> Result<GatewayRunResult> {
        // Resolve the defer queue by stepping simulated time to the
        // earlier of the next defer deadline and the target's next
        // internal event — not a fixed wall-step, which both overshot
        // deadlines (inflating `waited`) and wasted iterations when the
        // target was idle.
        while !self.queue.is_empty() {
            // lint:allow(D6, the while condition guarantees a non-empty queue)
            let deadline = self.next_defer_deadline().expect("non-empty queue");
            if self.target.now() + 1e-9 >= deadline {
                // Due now (the clock may have overshot by at most one
                // engine iteration): account the expiry at the deadline
                // itself so `waited` stays exact.
                self.sweep_at(deadline)?;
                continue;
            }
            match self.target.step_once()? {
                Some(stepped) => {
                    self.sweep_at(stepped.min(deadline))?;
                }
                None => {
                    // Idle target: jump straight to the deadline.
                    self.target.advance_to(deadline)?;
                    self.sweep_at(deadline)?;
                }
            }
        }
        // Drain the primary tier event by event so autoscaler events
        // (cold starts completing, scale-in holds expiring) keep firing
        // through the tail — otherwise idle replicas are charged
        // replica-seconds until the last request finishes.
        while let Some(stepped) = self.target.step_once()? {
            self.sync_spill(stepped)?;
            self.autoscale_step(stepped);
        }
        let per_replica = self.target.drain()?;
        let replica_seconds = self.target.replica_seconds(self.target.now());
        self.stats.surge_transitions = self.surge.transitions();
        self.stats.scale_out_requests = self.autoscaler.scale_out_requests();
        self.stats.scale_ins = self.autoscaler.retirements();
        let mut served = Vec::new();
        for m in &per_replica {
            for r in &m.requests {
                let s = served_outcome(r, &self.cfg);
                self.record_served(r, &s, false);
                served.push(s);
            }
        }
        let mut spilled = Vec::new();
        let mut spill_per_replica = Vec::new();
        let mut spill_replica_seconds = 0.0;
        if let Some(sp) = self.spill.as_mut() {
            spill_per_replica = sp.drain()?;
            spill_replica_seconds = sp.replica_seconds(sp.now());
        }
        for m in &spill_per_replica {
            for r in &m.requests {
                let s = served_outcome(r, &self.cfg);
                self.record_served(r, &s, true);
                spilled.push(s);
            }
        }
        Ok(GatewayRunResult {
            per_replica,
            served,
            spilled,
            spill_per_replica,
            rejections: self.rejections.clone(),
            stats: self.stats.clone(),
            replica_seconds,
            spill_replica_seconds,
        })
    }

    /// Run a whole trace through the gateway and finish. Non-finite
    /// arrivals are clamped to the trace origin (see
    /// [`Engine::load_trace`] for why they must not flow downstream).
    pub fn run_trace(&mut self, mut trace: Vec<RequestSpec>) -> Result<GatewayRunResult> {
        for s in &mut trace {
            if !s.arrival.is_finite() {
                s.arrival = 0.0;
            }
        }
        trace.sort_by(|a, b| a.arrival.total_cmp(&b.arrival));
        for spec in trace {
            self.submit(spec)?;
        }
        self.finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SchedulerConfig;
    use crate::coordinator::engine::EngineConfig;
    use crate::coordinator::sched::fcfs::FcfsScheduler;
    use crate::model::gpu::a100_4x;
    use crate::model::latency::LatencyModel;
    use crate::model::llm::opt_66b;
    use crate::util::stats::mean;
    use crate::workload::{ArrivalProcess, Dataset, QoeTrace, Workload};

    fn sim_engine(kv_tokens: usize) -> Engine<SimBackend, VirtualClock> {
        let latency = LatencyModel::for_deployment(&opt_66b(), &a100_4x());
        let cfg = EngineConfig {
            kv_capacity_tokens: kv_tokens,
            swap_capacity_tokens: kv_tokens * 2,
            ..EngineConfig::default()
        };
        Engine::new(
            cfg,
            SimBackend::new(latency.clone()),
            VirtualClock::default(),
            Box::new(FcfsScheduler::new()),
            latency,
        )
    }

    fn trace(n: usize, rate: f64, seed: u64) -> Vec<RequestSpec> {
        Workload {
            dataset: Dataset::ShareGpt,
            arrivals: ArrivalProcess::Poisson { rate },
            qoe_trace: QoeTrace::TextReading,
            num_requests: n,
            seed,
        }
        .generate()
    }

    fn disabled_cfg() -> GatewayConfig {
        GatewayConfig {
            admission_enabled: false,
            pacing_enabled: false,
            ..GatewayConfig::default()
        }
    }

    #[test]
    fn disabled_gateway_is_transparent() {
        // With admission and pacing off, the gateway must reproduce a
        // plain engine run exactly.
        let reqs = trace(40, 2.0, 11);
        let mut plain = sim_engine(100_000);
        plain.load_trace(reqs.clone());
        let plain_qoe = plain.run_to_completion().unwrap().avg_qoe();

        let mut gw = Gateway::new(sim_engine(100_000), disabled_cfg());
        let res = gw.run_trace(reqs).unwrap();
        assert_eq!(res.served.len(), 40);
        assert!(res.rejections.is_empty());
        let gw_qoe = mean(&res.served.iter().map(|s| s.paced_qoe).collect::<Vec<_>>());
        assert!((gw_qoe - plain_qoe).abs() < 1e-9, "gateway {gw_qoe} vs plain {plain_qoe}");
    }

    #[test]
    fn overload_sheds_and_protects_served_qoe() {
        // Far past capacity, the full gateway must reject some requests
        // and serve the admitted ones at better QoE than the unprotected
        // engine's average.
        let reqs = trace(120, 12.0, 7);
        let mut plain = sim_engine(2500);
        plain.load_trace(reqs.clone());
        let baseline = plain.run_to_completion().unwrap().avg_qoe();

        let mut cfg = GatewayConfig::default();
        cfg.surge.baseline_rate = 1.5;
        let mut gw = Gateway::new(sim_engine(2500), cfg);
        let res = gw.run_trace(reqs).unwrap();
        assert!(res.stats.rejected > 0, "no rejections under 8× overload");
        assert_eq!(res.served.len() + res.rejections.len(), 120, "request conservation");
        assert!(
            res.mean_served_qoe() > baseline,
            "served QoE {:.3} must beat unprotected {:.3}",
            res.mean_served_qoe(),
            baseline
        );
    }

    #[test]
    fn deferred_request_is_served_when_capacity_frees() {
        // Normal mode, a request that does not fit defers, then admits
        // once the running request finishes.
        let mut cfg = GatewayConfig::default();
        cfg.admission.max_defer_wait = 60.0;
        cfg.pacing_enabled = false;
        let mut gw = Gateway::new(sim_engine(2000), cfg);
        let mk = |id: usize, arrival: f64, prompt: usize| RequestSpec {
            id,
            arrival,
            prompt_tokens: prompt,
            output_tokens: 40,
            qoe: QoeSpec::new(1.0, 4.8),
            session: None,
        };
        assert_eq!(gw.submit(mk(0, 0.5, 1500)).unwrap(), SubmitOutcome::Admitted);
        assert_eq!(gw.submit(mk(1, 1.0, 1200)).unwrap(), SubmitOutcome::Deferred);
        let res = gw.finish().unwrap();
        assert_eq!(res.served.len(), 2, "deferred request must eventually serve");
        assert!(res.rejections.is_empty());
        assert_eq!(res.stats.deferred, 1);
        // The deferred request's wait is charged to its QoE (arrival
        // timestamp preserved): its QoE must trail the first request's.
        let q0 = res.served.iter().find(|s| s.id == 0).unwrap().raw_qoe;
        let q1 = res.served.iter().find(|s| s.id == 1).unwrap().raw_qoe;
        assert!(q1 < q0, "deferral must cost QoE: {q1} !< {q0}");
    }

    #[test]
    fn deferred_request_expires_at_deadline_not_next_arrival() {
        // Regression: the defer queue used to be re-examined only when
        // a new arrival called flush_deferred — under sparse post-burst
        // traffic a deferred request sat far past max_defer_wait and
        // was rejected with an inflated `waited`. It must now expire at
        // its own deadline.
        let mut cfg = GatewayConfig::default();
        cfg.pacing_enabled = false;
        cfg.admission.max_defer_wait = 2.0;
        let mut gw = Gateway::new(sim_engine(2000), cfg);
        let mk = |id: usize, arrival: f64, prompt: usize| RequestSpec {
            id,
            arrival,
            prompt_tokens: prompt,
            output_tokens: 200,
            qoe: QoeSpec::new(1.0, 4.8),
            session: None,
        };
        // Request 0 pins the KV for tens of seconds.
        assert_eq!(gw.submit(mk(0, 0.5, 1500)).unwrap(), SubmitOutcome::Admitted);
        // Request 1 cannot fit → deferred at t=1.0, deadline t=3.0.
        assert_eq!(gw.submit(mk(1, 1.0, 1200)).unwrap(), SubmitOutcome::Deferred);
        // Sparse traffic: the next arrival is 29 s later.
        let _ = gw.submit(mk(2, 30.0, 100)).unwrap();
        let rej: Vec<&Rejection> =
            gw.rejections().iter().filter(|r| r.id == 1).collect();
        assert_eq!(rej.len(), 1, "deferred request must have expired");
        assert!(
            rej[0].time < 3.5,
            "expired at t={} — deadline is 3.0, not the next arrival at 30",
            rej[0].time
        );
        match rej[0].reason {
            RejectReason::DeferTimeout { waited } => assert!(
                (waited - 2.0).abs() < 0.25,
                "waited {waited} must be ≈ max_defer_wait (2.0), not inflated to ~29"
            ),
            other => panic!("wrong reject reason {other:?}"),
        }
        gw.finish().unwrap();
    }

    #[test]
    fn deferred_request_gets_final_admission_check_at_deadline() {
        // Regression: a request whose deadline passed during an idle
        // gap used to be rejected even if it fit right then. The expiry
        // path must attempt admission first.
        let mut cfg = GatewayConfig::default();
        cfg.pacing_enabled = false;
        cfg.admission.max_defer_wait = 5.0;
        let mut gw = Gateway::new(sim_engine(2000), cfg);
        let mk = |id: usize, arrival: f64, prompt: usize, output: usize| RequestSpec {
            id,
            arrival,
            prompt_tokens: prompt,
            output_tokens: output,
            qoe: QoeSpec::new(1.0, 4.8),
            session: None,
        };
        // Request 0 fills the KV but finishes well before request 1's
        // deadline (t=6.0); the next arrival is far later.
        assert_eq!(gw.submit(mk(0, 0.5, 1500, 15)).unwrap(), SubmitOutcome::Admitted);
        assert_eq!(gw.submit(mk(1, 1.0, 1200, 40)).unwrap(), SubmitOutcome::Deferred);
        let _ = gw.submit(mk(2, 40.0, 100, 20)).unwrap();
        let res = gw.finish().unwrap();
        assert!(
            res.rejections.iter().all(|r| r.id != 1),
            "request 1 fit at its deadline and must not expire"
        );
        // Engine ids follow submission order, so spec id 1 is engine
        // request 1.
        let r1 = res.per_replica[0].requests.iter().find(|r| r.id == 1).unwrap();
        assert!(
            r1.token_times[0] < 10.0,
            "first token at t={} — admission happened at the deadline sweep \
             (t=6.0), not at the next arrival (t=40)",
            r1.token_times[0]
        );
    }

    #[test]
    fn spill_tier_replays_rejections_and_conserves_requests() {
        // Far past primary capacity, sheds are replayed on the spill
        // cluster instead of being dropped.
        let reqs = trace(120, 12.0, 7);
        let mut cfg = GatewayConfig::default();
        cfg.pacing_enabled = false;
        cfg.surge.baseline_rate = 1.5;
        let latency = LatencyModel::for_deployment(&opt_66b(), &a100_4x());
        let ecfg = EngineConfig {
            kv_capacity_tokens: 8000,
            swap_capacity_tokens: 16_000,
            ..EngineConfig::default()
        };
        let spill = Cluster::new(
            2,
            ecfg,
            latency,
            &SchedulerConfig::Fcfs,
            RoutingPolicy::LeastLoaded,
        );
        let mut gw = Gateway::with_spill(sim_engine(2500), cfg, spill);
        let res = gw.run_trace(reqs).unwrap();
        assert!(res.stats.spilled > 0, "8× overload must spill");
        assert_eq!(res.spilled.len(), res.stats.spilled, "every spill must serve");
        // Conservation across both tiers.
        assert_eq!(res.served.len() + res.spilled.len() + res.rejections.len(), 120);
        assert_eq!(
            res.stats.admitted + res.stats.spilled + res.stats.rejected,
            res.stats.arrivals
        );
        assert!(res.spill_replica_seconds > 0.0);
    }

    #[test]
    fn spill_wait_is_charged_to_original_arrival() {
        let mut cfg = GatewayConfig::default();
        cfg.pacing_enabled = false;
        cfg.admission.max_defer_wait = 3.0;
        let latency = LatencyModel::for_deployment(&opt_66b(), &a100_4x());
        let ecfg = EngineConfig {
            kv_capacity_tokens: 100_000,
            swap_capacity_tokens: 200_000,
            ..EngineConfig::default()
        };
        let spill = Cluster::new(
            1,
            ecfg,
            latency,
            &SchedulerConfig::Fcfs,
            RoutingPolicy::LeastLoaded,
        );
        let mut gw = Gateway::with_spill(sim_engine(2000), cfg, spill);
        let mk = |id: usize, arrival: f64, prompt: usize, output: usize| RequestSpec {
            id,
            arrival,
            prompt_tokens: prompt,
            output_tokens: output,
            qoe: QoeSpec::new(1.0, 4.8),
            session: None,
        };
        // Request 0 pins the primary; request 1 defers at t=1.0 and
        // times out at t=4.0, spilling onto an idle overflow replica.
        assert_eq!(gw.submit(mk(0, 0.5, 1500, 200)).unwrap(), SubmitOutcome::Admitted);
        assert_eq!(gw.submit(mk(1, 1.0, 1200, 40)).unwrap(), SubmitOutcome::Deferred);
        let res = gw.finish().unwrap();
        assert_eq!(res.stats.spilled, 1);
        assert_eq!(res.spilled.len(), 1);
        assert!(res.rejections.is_empty());
        // The spill engine preserved the original arrival, so the
        // 3 s defer wait plus replay is charged to the request's QoE.
        let rec = &res.spill_per_replica[0].requests[0];
        assert!((rec.arrival - 1.0).abs() < 1e-9, "arrival {}", rec.arrival);
        assert!(
            rec.token_times[0] >= 4.0 - 1e-9,
            "service starts after the defer timeout, got {}",
            rec.token_times[0]
        );
        assert!(
            res.spilled[0].raw_qoe < 1.0 - 1e-6,
            "the spill wait must cost QoE (got {})",
            res.spilled[0].raw_qoe
        );
    }

    #[test]
    fn autoscaler_grows_and_shrinks_the_cluster() {
        let latency = LatencyModel::for_deployment(&opt_66b(), &a100_4x());
        let ecfg = EngineConfig {
            kv_capacity_tokens: 8000,
            swap_capacity_tokens: 16_000,
            ..EngineConfig::default()
        };
        let cluster = Cluster::new(
            1,
            ecfg,
            latency,
            &SchedulerConfig::Fcfs,
            RoutingPolicy::LeastLoaded,
        );
        let mut cfg = GatewayConfig::default();
        cfg.pacing_enabled = false;
        cfg.surge.baseline_rate = 100.0; // keep shedding out of the way
        cfg.autoscale = AutoscaleConfig {
            enabled: true,
            min_replicas: 1,
            max_replicas: 3,
            replica_capacity: 1.0,
            target_utilization: 1.0,
            cold_start_secs: 2.0,
            scale_in_hold_secs: 10.0,
            kv_high_watermark: 0.95,
            eval_interval_secs: 0.5,
        };
        let mut gw = Gateway::new(cluster, cfg);
        // A 15 s burst at 6 req/s, then sparse stragglers.
        let mut reqs: Vec<RequestSpec> = (0..90)
            .map(|i| RequestSpec {
                id: i,
                arrival: 0.5 + i as f64 / 6.0,
                prompt_tokens: 150,
                output_tokens: 30,
                qoe: QoeSpec::new(1.0, 4.8),
                session: None,
            })
            .collect();
        for k in 0..4usize {
            reqs.push(RequestSpec {
                id: 90 + k,
                arrival: 40.0 + 15.0 * k as f64,
                prompt_tokens: 100,
                output_tokens: 20,
                qoe: QoeSpec::new(1.0, 4.8),
                session: None,
            });
        }
        let res = gw.run_trace(reqs).unwrap();
        assert!(res.stats.scale_out_requests >= 1, "burst must trigger scale-out");
        assert!(res.stats.scale_ins >= 1, "quiet tail must retire replicas");
        assert_eq!(res.served_count() + res.rejections.len(), 94, "conservation");
        assert!(gw.target().num_replicas() > 1, "cluster must have grown");
        assert_eq!(
            gw.target().routable_count(),
            1,
            "the tail must shrink routing back to min_replicas"
        );
        assert!(res.replica_seconds > 0.0);
    }

    #[test]
    fn pacing_reduces_early_tokens_without_qoe_loss() {
        let mut cfg = GatewayConfig::default();
        cfg.admission_enabled = false;
        cfg.pacing_enabled = true;
        let mut gw = Gateway::new(sim_engine(100_000), cfg);
        // Light load → heavy overfast generation.
        let res = gw.run_trace(trace(30, 0.5, 3)).unwrap();
        let (raw, paced) = res.early_token_fractions();
        assert!(raw > 0.2, "light load should generate ahead of digestion ({raw})");
        assert!(paced < raw, "pacing must reduce early tokens ({paced} !< {raw})");
        for s in &res.served {
            assert!(
                s.paced_qoe >= s.raw_qoe - 1e-6,
                "pacing lowered QoE on {}: {} < {}",
                s.id,
                s.paced_qoe,
                s.raw_qoe
            );
        }
    }

    #[test]
    fn cluster_target_routes_and_completes() {
        let latency = LatencyModel::for_deployment(&opt_66b(), &a100_4x());
        let ecfg = EngineConfig {
            kv_capacity_tokens: 8000,
            swap_capacity_tokens: 16_000,
            ..EngineConfig::default()
        };
        let cluster = Cluster::new(
            3,
            ecfg,
            latency,
            &SchedulerConfig::Fcfs,
            RoutingPolicy::QoeAware,
        );
        let mut gw = Gateway::new(cluster, disabled_cfg());
        let res = gw.run_trace(trace(60, 3.0, 5)).unwrap();
        assert_eq!(res.served.len(), 60);
        assert_eq!(res.per_replica.len(), 3);
        let total: usize = res.per_replica.iter().map(|m| m.requests.len()).sum();
        assert_eq!(total, 60);
    }

    #[test]
    fn premium_jumps_the_defer_queue() {
        // Two requests defer behind a KV-pinning request; the premium
        // one arrived *later* but carries weight 2, so it re-attempts
        // admission first once capacity frees. With uniform weights the
        // queue is FIFO and the standard request would have gone first.
        let mut cfg = GatewayConfig::default();
        cfg.pacing_enabled = false;
        cfg.admission.max_defer_wait = 120.0;
        cfg.admission.tier_weights =
            TierWeights { premium: 2.0, standard: 1.0, economy: 0.5 };
        let mut gw = Gateway::new(sim_engine(2000), cfg);
        let mk = |id: usize, arrival: f64, qoe: QoeSpec| RequestSpec {
            id,
            arrival,
            prompt_tokens: 1200,
            output_tokens: 40,
            qoe,
            session: None,
        };
        let pin = RequestSpec {
            id: 0,
            arrival: 0.5,
            prompt_tokens: 1500,
            output_tokens: 60,
            qoe: QoeSpec::new(1.0, 4.8),
            session: None,
        };
        assert_eq!(gw.submit(pin).unwrap(), SubmitOutcome::Admitted);
        let standard = QoeSpec::new(1.0, 4.8);
        let premium = QoeSpec::new(0.5, 6.5);
        assert_eq!(gw.submit(mk(1, 1.0, standard)).unwrap(), SubmitOutcome::Deferred);
        assert_eq!(gw.submit(mk(2, 1.2, premium)).unwrap(), SubmitOutcome::Deferred);
        let res = gw.finish().unwrap();
        assert_eq!(res.served.len(), 3, "everything must eventually serve");
        assert!(res.rejections.is_empty());
        // Engine ids follow admission order, so identify the deferred
        // pair by their preserved arrival timestamps.
        let reqs = &res.per_replica[0].requests;
        let std_first = reqs
            .iter()
            .find(|r| (r.arrival - 1.0).abs() < 1e-9)
            .unwrap()
            .token_times[0];
        let prem_first = reqs
            .iter()
            .find(|r| (r.arrival - 1.2).abs() < 1e-9)
            .unwrap()
            .token_times[0];
        assert!(
            prem_first < std_first,
            "premium (first token {prem_first}) must be admitted before \
             standard (first token {std_first})"
        );
    }

    #[test]
    fn session_cluster_through_gateway_hits_prefixes() {
        // A session workload through the full gateway over a
        // park+affinity cluster: returning turns find their parked
        // prefixes, and request conservation still holds.
        use crate::workload::SessionWorkload;
        let latency = LatencyModel::for_deployment(&opt_66b(), &a100_4x());
        let ecfg = EngineConfig {
            kv_capacity_tokens: 16_000,
            swap_capacity_tokens: 32_000,
            park_prefixes: true,
            ..EngineConfig::default()
        };
        let mut cluster = Cluster::new(
            2,
            ecfg,
            latency,
            &SchedulerConfig::Fcfs,
            RoutingPolicy::QoeAware,
        );
        cluster.set_session_affinity(true);
        let mut cfg = GatewayConfig::default();
        cfg.pacing_enabled = false;
        let trace = SessionWorkload {
            num_sessions: 20,
            arrivals: ArrivalProcess::Poisson { rate: 0.5 },
            qoe_trace: QoeTrace::TextReading,
            min_turns: 2,
            max_turns: 4,
            think_time_mean: 3.0,
            seed: 11,
        }
        .generate();
        let n = trace.len();
        let returning =
            trace.iter().filter(|r| r.session.is_some_and(|s| s.is_returning())).count();
        assert!(returning >= 20);
        let mut gw = Gateway::new(cluster, cfg);
        let res = gw.run_trace(trace).unwrap();
        assert_eq!(res.served.len() + res.rejections.len(), n, "conservation");
        let hits: u64 = res.per_replica.iter().map(|m| m.prefix_hits).sum();
        let parked: u64 = res.per_replica.iter().map(|m| m.prefixes_parked).sum();
        assert!(parked > 0, "turns expecting a return must park");
        assert!(hits > 0, "lightly loaded returning turns must hit parked prefixes");
        assert!(hits <= returning as u64);
    }

    #[test]
    fn early_token_counter_matches_intuition() {
        let spec = QoeSpec::new(1.0, 2.0);
        // Burst of 5 at t=1: the first displays immediately, 4 are early.
        assert_eq!(count_early_tokens(&spec, &[1.0, 1.0, 1.0, 1.0, 1.0]), 4);
        // Exactly paced delivery: never early.
        let paced: Vec<f64> = (0..6).map(|i| 1.0 + i as f64 / 2.0).collect();
        assert_eq!(count_early_tokens(&spec, &paced), 0);
    }
}
