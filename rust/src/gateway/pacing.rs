//! Server-side token delivery pacing.
//!
//! The engine generates tokens as fast as the batch allows — often far
//! faster than the user's digestion speed (the paper's "overfast
//! generation", Fig. 3). The client-side buffer (paper §5) hides the
//! jitter, but the *server* still pays to push tokens nobody can read
//! yet. The gateway pacer shapes delivery at the server: each request's
//! tokens are released at its expected TDS (times a configurable safety
//! factor), after letting a small lead buffer through unpaced so the
//! client always has a few tokens in hand against network jitter and
//! short preemptions.
//!
//! Invariants (tested below):
//! - conservation: every pushed token is eventually released, in order;
//! - a release never precedes its token's generation time;
//! - paced releases are spaced at least `1/(tds × rate_factor)` apart
//!   once the lead buffer has passed.
//!
//! ```
//! use andes::gateway::{pace_times, PacingConfig};
//! use andes::qoe::spec::QoeSpec;
//!
//! // 5 tokens generated in one burst at t=1, digested at 4 tok/s.
//! let spec = QoeSpec::new(1.0, 4.0);
//! let cfg = PacingConfig { rate_factor: 1.0, lead_tokens: 2 };
//! let released = pace_times(&spec, &cfg, &[1.0; 5]);
//! // Two lead tokens pass through; the rest are spaced 0.25 s apart.
//! assert_eq!(released, vec![1.0, 1.0, 1.25, 1.5, 1.75]);
//!
//! // lead_tokens: 0 really means zero lead — every token is paced,
//! // including the first (it releases at its own generation time).
//! let none = PacingConfig { rate_factor: 1.0, lead_tokens: 0 };
//! assert_eq!(pace_times(&spec, &none, &[1.0; 3]), vec![1.0, 1.25, 1.5]);
//! ```

use std::collections::VecDeque;

use crate::qoe::spec::QoeSpec;

/// Pacing configuration.
#[derive(Debug, Clone)]
pub struct PacingConfig {
    /// Release-rate multiplier on the request's expected TDS. Values
    /// slightly above 1 keep the client buffer from ever running dry
    /// while still reclaiming almost all of the overfast surplus.
    pub rate_factor: f64,
    /// Tokens let through unpaced to build the client-side lead buffer.
    /// 0 disables the lead entirely: every token (including the first)
    /// is released at the paced rate.
    pub lead_tokens: usize,
}

impl Default for PacingConfig {
    fn default() -> Self {
        PacingConfig { rate_factor: 1.25, lead_tokens: 4 }
    }
}

/// Per-request delivery shaper.
#[derive(Debug, Clone)]
pub struct TokenPacer {
    /// Minimum spacing between paced releases (s); 0 = pass-through.
    interval: f64,
    /// Unpaced-release budget target: tokens released unpaced before
    /// the interval applies. May be raised mid-stream (see
    /// [`TokenPacer::set_lead`]).
    lead: usize,
    /// Unpaced releases consumed so far (≤ `lead`).
    unpaced_used: usize,
    /// Generation timestamps of tokens not yet released.
    pending: VecDeque<f64>,
    released: usize,
    /// Release time of the most recently released token.
    last_release: f64,
}

impl TokenPacer {
    pub fn new(spec: &QoeSpec, cfg: &PacingConfig) -> Self {
        assert!(cfg.rate_factor > 0.0, "rate factor must be positive");
        TokenPacer {
            interval: 1.0 / (spec.tds * cfg.rate_factor),
            lead: cfg.lead_tokens,
            unpaced_used: 0,
            pending: VecDeque::new(),
            released: 0,
            last_release: f64::NEG_INFINITY,
        }
    }

    /// A pacer that releases every token immediately (pacing disabled).
    pub fn passthrough() -> Self {
        TokenPacer {
            interval: 0.0,
            lead: usize::MAX,
            unpaced_used: 0,
            pending: VecDeque::new(),
            released: 0,
            last_release: f64::NEG_INFINITY,
        }
    }

    /// Retarget the lead buffer mid-stream (the jitter-adaptive mode,
    /// [`crate::delivery`]). Growing the lead grants immediate unpaced
    /// budget — the pacer bursts the difference to refill the client
    /// buffer; shrinking it only limits future unpaced releases (tokens
    /// already on the wire are not clawed back). With a constant lead
    /// this is exactly the static behavior.
    pub fn set_lead(&mut self, lead: usize) {
        self.lead = lead;
    }

    /// Current lead-token target.
    pub fn lead(&self) -> usize {
        self.lead
    }

    /// Release time of the most recently released token
    /// (`NEG_INFINITY` before the first release).
    pub fn last_release(&self) -> f64 {
        self.last_release
    }

    /// Record a token generated at time `t`.
    pub fn push(&mut self, generated_at: f64) {
        self.pending.push_back(generated_at);
    }

    /// Record `n` tokens generated at time `t`.
    pub fn push_n(&mut self, generated_at: f64, n: usize) {
        for _ in 0..n {
            self.pending.push_back(generated_at);
        }
    }

    /// Earliest time the next pending token may be released.
    pub fn next_due(&self) -> Option<f64> {
        self.pending.front().map(|&gen_t| self.due_time(gen_t))
    }

    fn due_time(&self, gen_t: f64) -> f64 {
        if self.unpaced_used < self.lead {
            gen_t.max(self.last_release)
        } else {
            gen_t.max(self.last_release + self.interval)
        }
    }

    /// Release every token due by `now`; returns how many were released.
    /// Calls must use non-decreasing `now`.
    pub fn release_due(&mut self, now: f64) -> usize {
        let mut n = 0;
        while let Some(&gen_t) = self.pending.front() {
            let unpaced = self.unpaced_used < self.lead;
            let due = self.due_time(gen_t);
            if due > now {
                break;
            }
            self.pending.pop_front();
            self.released += 1;
            if unpaced {
                self.unpaced_used += 1;
            }
            self.last_release = due;
            n += 1;
        }
        n
    }

    /// Tokens generated but not yet released.
    pub fn pending(&self) -> usize {
        self.pending.len()
    }

    /// Tokens released so far.
    pub fn released(&self) -> usize {
        self.released
    }
}

/// Batch form of the pacer for trace post-processing: map generation
/// times to release times under the same policy as [`TokenPacer`].
/// Times are request-relative and must be non-decreasing.
pub fn pace_times(spec: &QoeSpec, cfg: &PacingConfig, times: &[f64]) -> Vec<f64> {
    let interval = 1.0 / (spec.tds * cfg.rate_factor);
    let lead = cfg.lead_tokens;
    let mut out = Vec::with_capacity(times.len());
    let mut last = f64::NEG_INFINITY;
    for (i, &t) in times.iter().enumerate() {
        let r = if i < lead { t.max(last) } else { t.max(last + interval) };
        out.push(r);
        last = r;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> QoeSpec {
        QoeSpec::new(1.0, 4.0) // 4 tok/s digestion
    }

    fn cfg() -> PacingConfig {
        PacingConfig { rate_factor: 1.0, lead_tokens: 2 } // 0.25 s interval
    }

    #[test]
    fn conservation_in_order() {
        // Every generated token is eventually released, exactly once, in
        // order, never before its generation time.
        let mut p = TokenPacer::new(&spec(), &cfg());
        let gen_times: Vec<f64> = vec![0.5, 0.5, 0.5, 0.5, 0.9, 2.0, 2.0, 2.0, 5.0, 5.1];
        for &t in &gen_times {
            p.push(t);
        }
        let mut releases: Vec<f64> = Vec::new();
        let mut now = 0.0;
        while p.pending() > 0 {
            now += 0.05;
            let n = p.release_due(now);
            for _ in 0..n {
                releases.push(now);
            }
            assert!(now < 100.0, "pacer failed to drain");
        }
        assert_eq!(releases.len(), gen_times.len(), "token conservation");
        assert_eq!(p.released(), gen_times.len());
        assert!(releases.windows(2).all(|w| w[1] >= w[0]), "in-order release");
        for (r, g) in releases.iter().zip(&gen_times) {
            assert!(r + 1e-9 >= *g, "released {r} before generated {g}");
        }
    }

    #[test]
    fn lead_burst_passes_then_paced() {
        let mut p = TokenPacer::new(&spec(), &cfg());
        p.push_n(1.0, 6);
        // At t=1.0: the 2 lead tokens go out immediately, the rest wait.
        assert_eq!(p.release_due(1.0), 2);
        // Paced at 0.25 s spacing afterwards.
        assert_eq!(p.release_due(1.24), 0);
        assert_eq!(p.release_due(1.25), 1);
        assert_eq!(p.release_due(1.75), 2);
        assert_eq!(p.release_due(10.0), 1);
        assert_eq!(p.pending(), 0);
    }

    #[test]
    fn slow_generation_passes_through() {
        // Generation slower than the pacing rate: release == generation.
        let mut p = TokenPacer::new(&spec(), &cfg());
        for i in 0..5 {
            let t = 1.0 + i as f64; // 1 tok/s < 4 tok/s
            p.push(t);
            assert_eq!(p.release_due(t), 1, "token {i} should pass straight through");
        }
    }

    #[test]
    fn zero_lead_means_no_unpaced_tokens() {
        // Regression: `TokenPacer::new` used to promote `lead_tokens: 0`
        // to 1, so the lead buffer could never actually be disabled.
        // With zero lead, a burst drains strictly at the pacing rate —
        // one token per interval, the first at its own generation time.
        let c = PacingConfig { rate_factor: 1.0, lead_tokens: 0 };
        let mut p = TokenPacer::new(&spec(), &c);
        p.push_n(1.0, 4);
        assert_eq!(p.release_due(1.0), 1, "first token paced, not passed through");
        assert_eq!(p.release_due(1.24), 0);
        assert_eq!(p.release_due(1.25), 1);
        assert_eq!(p.release_due(2.0), 2);
        assert_eq!(p.pending(), 0);
        // The batch form agrees.
        assert_eq!(
            pace_times(&spec(), &c, &[1.0, 1.0, 1.0, 1.0]),
            vec![1.0, 1.25, 1.5, 1.75]
        );
    }

    #[test]
    fn raising_lead_mid_stream_grants_unpaced_budget() {
        // The adaptive mode's contract: growing the lead from L to L+Δ
        // after the original budget was spent releases Δ more tokens
        // unpaced (refilling the client buffer), then pacing resumes.
        let mut p = TokenPacer::new(&spec(), &cfg()); // lead 2, 0.25 s
        p.push_n(1.0, 10);
        assert_eq!(p.release_due(1.0), 2, "static lead of 2 passes");
        assert_eq!(p.release_due(1.5), 2, "paced at 1.25, 1.5");
        p.set_lead(5); // +3 budget (2 already used)
        assert_eq!(p.release_due(1.5), 3, "the raise bursts immediately");
        assert_eq!(p.release_due(1.74), 0, "then pacing resumes");
        assert_eq!(p.release_due(1.75), 1);
        // Shrinking below what was used never claws anything back and
        // simply leaves the pacer in paced mode.
        p.set_lead(1);
        assert_eq!(p.release_due(2.0), 1);
        assert_eq!(p.release_due(10.0), 1);
        assert_eq!(p.pending(), 0);
        assert_eq!(p.released(), 10);
    }

    #[test]
    fn passthrough_never_delays() {
        let mut p = TokenPacer::passthrough();
        p.push_n(0.5, 100);
        assert_eq!(p.release_due(0.5), 100);
    }

    #[test]
    fn incremental_matches_batch() {
        // release_due must realize exactly the schedule pace_times computes:
        // stepping the pacer to each expected release instant yields at
        // least that many cumulative releases, and nothing earlier.
        let sp = spec();
        let c = cfg();
        let gen_times: Vec<f64> = vec![0.2, 0.2, 0.2, 1.0, 1.0, 1.0, 1.0, 3.0, 3.01, 3.02];
        let expect = pace_times(&sp, &c, &gen_times);
        let mut p = TokenPacer::new(&sp, &c);
        for &t in &gen_times {
            p.push(t);
        }
        let mut total = 0;
        let mut prev = f64::NEG_INFINITY;
        for (i, &e) in expect.iter().enumerate() {
            if e > prev + 1e-9 {
                // Nothing may be due strictly before the next expected
                // instant (skip when several tokens share one instant).
                total += p.release_due(e - 1e-6);
                assert!(total <= i, "released early before {e}");
                prev = e;
            }
            total += p.release_due(e);
            assert!(total >= i + 1, "by t={e} expected {} releases, got {total}", i + 1);
        }
        assert_eq!(total, expect.len());
        assert_eq!(p.pending(), 0);
    }

    #[test]
    fn pace_times_monotone_and_bounded() {
        let sp = spec();
        let c = PacingConfig { rate_factor: 1.25, lead_tokens: 4 };
        let times: Vec<f64> = (0..50).map(|i| 0.5 + 0.01 * i as f64).collect();
        let paced = pace_times(&sp, &c, &times);
        assert_eq!(paced.len(), times.len());
        assert!(paced.windows(2).all(|w| w[1] >= w[0]));
        for (p, t) in paced.iter().zip(&times) {
            assert!(p >= t);
        }
        // After the lead, spacing is at least the pacing interval.
        let interval = 1.0 / (sp.tds * c.rate_factor);
        for w in paced[c.lead_tokens..].windows(2) {
            assert!(w[1] - w[0] >= interval - 1e-9);
        }
    }
}
