//! Windowed arrival-rate estimation and surge detection.
//!
//! The gateway needs to know *when* to switch admission from its
//! permissive normal mode into surge mode (shed instead of queue,
//! reroute onto the least-loaded replica). A sliding-window rate
//! estimator with enter/exit hysteresis does that: the mode enters
//! Surge when the windowed arrival rate exceeds `enter_factor ×
//! baseline_rate` and only returns to Normal once it falls below
//! `exit_factor × baseline_rate`, so rates hovering at the threshold
//! cannot flap the mode (and with it, admission decisions).
//!
//! ```
//! use andes::gateway::{LoadMode, SurgeConfig, SurgeDetector};
//!
//! // Baseline 2 req/s; an 8 req/s burst must flip the mode to Surge.
//! let mut det = SurgeDetector::new(SurgeConfig {
//!     baseline_rate: 2.0,
//!     ..SurgeConfig::default()
//! });
//! for i in 1..=40 {
//!     det.observe(i as f64 / 8.0);
//! }
//! assert_eq!(det.mode(), LoadMode::Surge);
//! assert!(det.rate_at(5.0) > 3.0);
//! ```

use std::collections::VecDeque;

/// The gateway's load regime.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LoadMode {
    /// Arrival rate within sustainable capacity: queue, never shed.
    Normal,
    /// Arrival surge: shed load that cannot be served at acceptable QoE.
    Surge,
}

impl LoadMode {
    pub fn label(&self) -> &'static str {
        match self {
            LoadMode::Normal => "normal",
            LoadMode::Surge => "surge",
        }
    }
}

/// Surge detector configuration.
#[derive(Debug, Clone)]
pub struct SurgeConfig {
    /// Sliding window length for the rate estimate (s).
    pub window_secs: f64,
    /// Sustainable arrival rate of the deployment (req/s) — typically the
    /// analytic capacity estimate of the serving tier behind the gateway.
    pub baseline_rate: f64,
    /// Enter Surge above `enter_factor × baseline_rate`.
    pub enter_factor: f64,
    /// Leave Surge below `exit_factor × baseline_rate` (< enter_factor:
    /// the gap is the hysteresis band).
    pub exit_factor: f64,
    /// Minimum arrivals in the window before the estimate is trusted.
    pub min_arrivals: usize,
}

impl Default for SurgeConfig {
    fn default() -> Self {
        SurgeConfig {
            window_secs: 10.0,
            baseline_rate: 3.0,
            enter_factor: 1.5,
            exit_factor: 1.1,
            min_arrivals: 8,
        }
    }
}

/// Sliding-window arrival-rate estimator with hysteresis mode switching.
#[derive(Debug, Clone)]
pub struct SurgeDetector {
    cfg: SurgeConfig,
    /// Arrival timestamps inside the current window, oldest first.
    arrivals: VecDeque<f64>,
    /// First arrival ever observed (survives window eviction): before a
    /// full window has elapsed, the rate is estimated over the observed
    /// span rather than the window length.
    first_arrival: Option<f64>,
    mode: LoadMode,
    transitions: u64,
}

impl SurgeDetector {
    pub fn new(cfg: SurgeConfig) -> Self {
        assert!(cfg.window_secs > 0.0, "window must be positive");
        assert!(cfg.baseline_rate > 0.0, "baseline rate must be positive");
        assert!(
            cfg.enter_factor > cfg.exit_factor,
            "enter factor must exceed exit factor (hysteresis band)"
        );
        SurgeDetector {
            cfg,
            arrivals: VecDeque::new(),
            first_arrival: None,
            mode: LoadMode::Normal,
            transitions: 0,
        }
    }

    pub fn config(&self) -> &SurgeConfig {
        &self.cfg
    }

    /// Record an arrival at time `t` (monotone) and update the mode.
    pub fn observe(&mut self, t: f64) {
        if self.first_arrival.is_none() {
            self.first_arrival = Some(t);
        }
        self.arrivals.push_back(t);
        let cutoff = t - self.cfg.window_secs;
        while self.arrivals.front().is_some_and(|&a| a < cutoff) {
            self.arrivals.pop_front();
        }
        self.update_mode(t);
    }

    /// Windowed arrival-rate estimate (req/s) as of time `t`.
    ///
    /// Before a full window has elapsed since the first arrival, the
    /// count is divided by the *observed span* rather than the window
    /// length — dividing by the full window underestimates the rate at
    /// cold start and delays surge entry during an opening burst. The
    /// span is floored at a tenth of the window so a tight opening
    /// burst cannot produce an unbounded estimate.
    pub fn rate_at(&self, t: f64) -> f64 {
        let cutoff = t - self.cfg.window_secs;
        let n = self.arrivals.iter().filter(|&&a| a >= cutoff).count();
        let span = match self.first_arrival {
            Some(first) => {
                (t - first).min(self.cfg.window_secs).max(self.cfg.window_secs * 0.1)
            }
            None => self.cfg.window_secs,
        };
        n as f64 / span
    }

    pub fn mode(&self) -> LoadMode {
        self.mode
    }

    /// Number of Normal↔Surge transitions so far (flap diagnostics).
    pub fn transitions(&self) -> u64 {
        self.transitions
    }

    fn update_mode(&mut self, t: f64) {
        let rate = self.rate_at(t);
        // The min_arrivals guard gates only *entering* Surge (don't trust
        // a thin sample); the exit must stay live even under sparse
        // post-surge traffic, or the mode latches in Surge forever.
        let next = match self.mode {
            LoadMode::Normal
                if self.arrivals.len() >= self.cfg.min_arrivals
                    && rate > self.cfg.enter_factor * self.cfg.baseline_rate =>
            {
                LoadMode::Surge
            }
            LoadMode::Surge if rate < self.cfg.exit_factor * self.cfg.baseline_rate => {
                LoadMode::Normal
            }
            same => same,
        };
        if next != self.mode {
            self.mode = next;
            self.transitions += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn detector() -> SurgeDetector {
        // baseline 2 req/s, enter above 3, exit below 2.2, 5 s window.
        SurgeDetector::new(SurgeConfig {
            window_secs: 5.0,
            baseline_rate: 2.0,
            enter_factor: 1.5,
            exit_factor: 1.1,
            min_arrivals: 4,
        })
    }

    /// Feed `n` arrivals at a constant rate starting at `t0`.
    fn feed(d: &mut SurgeDetector, t0: f64, rate: f64, n: usize) -> f64 {
        let mut t = t0;
        for _ in 0..n {
            t += 1.0 / rate;
            d.observe(t);
        }
        t
    }

    #[test]
    fn steady_load_stays_normal() {
        let mut d = detector();
        feed(&mut d, 0.0, 2.0, 60);
        assert_eq!(d.mode(), LoadMode::Normal);
        assert_eq!(d.transitions(), 0);
    }

    #[test]
    fn burst_enters_surge_then_recovers() {
        let mut d = detector();
        let t = feed(&mut d, 0.0, 2.0, 20);
        assert_eq!(d.mode(), LoadMode::Normal);
        let t = feed(&mut d, t, 8.0, 60); // 4× burst
        assert_eq!(d.mode(), LoadMode::Surge);
        // Back to baseline: the window drains below the exit threshold.
        feed(&mut d, t, 1.0, 30);
        assert_eq!(d.mode(), LoadMode::Normal);
        assert_eq!(d.transitions(), 2);
    }

    #[test]
    fn rate_estimate_tracks_window() {
        let mut d = detector();
        let t = feed(&mut d, 0.0, 4.0, 40);
        let r = d.rate_at(t);
        assert!((r - 4.0).abs() < 0.5, "rate {r}");
    }

    #[test]
    fn hysteresis_band_does_not_flap() {
        // Rate oscillating between the exit and enter thresholds (2.2–3.0
        // req/s here) must hold whatever mode it is in: at most the one
        // transition that first entered Surge.
        let mut d = detector();
        let mut t = feed(&mut d, 0.0, 8.0, 40); // enter surge
        assert_eq!(d.mode(), LoadMode::Surge);
        let before = d.transitions();
        for _ in 0..20 {
            t = feed(&mut d, t, 2.8, 10); // inside the band
            t = feed(&mut d, t, 2.4, 10); // still inside the band
        }
        assert_eq!(d.mode(), LoadMode::Surge);
        assert_eq!(d.transitions(), before, "mode flapped inside the band");
    }

    #[test]
    fn sparse_traffic_still_exits_surge() {
        // After a burst, near-dead traffic (fewer than min_arrivals in
        // the window) must still release the Surge latch.
        let mut d = detector();
        let t = feed(&mut d, 0.0, 8.0, 40);
        assert_eq!(d.mode(), LoadMode::Surge);
        feed(&mut d, t, 0.2, 4); // 1 arrival / 5 s — window nearly empty
        assert_eq!(d.mode(), LoadMode::Normal);
    }

    #[test]
    fn cold_start_rate_uses_observed_span() {
        // 8 req/s for one second into an empty 5 s window: dividing by
        // the full window would report ~1.6 req/s; the estimate must
        // track the actual opening rate instead.
        let mut d = detector();
        let t = feed(&mut d, 0.0, 8.0, 8);
        let r = d.rate_at(t);
        assert!(r > 6.0, "cold-start rate underestimated: {r}");
    }

    #[test]
    fn opening_burst_enters_surge_promptly() {
        // An 8 req/s burst from a cold start must flip to Surge as soon
        // as min_arrivals trusts the sample — not only after enough
        // arrivals to fill the whole window.
        let mut d = detector();
        let mut n = 0;
        let mut t = 0.0;
        while d.mode() == LoadMode::Normal && n < 40 {
            t += 1.0 / 8.0;
            d.observe(t);
            n += 1;
        }
        assert_eq!(d.mode(), LoadMode::Surge);
        assert!(n <= 6, "surge entry took {n} arrivals (window-fill lag)");
    }

    #[test]
    fn too_few_arrivals_keep_normal() {
        let mut d = detector();
        // 3 arrivals in a burst — below min_arrivals, no mode change.
        for t in [0.0, 0.01, 0.02] {
            d.observe(t);
        }
        assert_eq!(d.mode(), LoadMode::Normal);
    }

    #[test]
    #[should_panic]
    fn rejects_inverted_hysteresis() {
        SurgeDetector::new(SurgeConfig {
            enter_factor: 1.0,
            exit_factor: 1.5,
            ..SurgeConfig::default()
        });
    }
}
