//! Predictive autoscaling: turn the surge detector's windowed rate
//! estimate into a target replica count, ahead of the scheduler.
//!
//! The paper's headline efficiency result (61% GPU savings at equal
//! QoE) presumes an *elastic* serving tier: capacity follows demand
//! instead of being provisioned for the peak. The
//! [`PredictiveAutoscaler`] closes that loop at the gateway, where the
//! arrival-rate estimate already lives (cf. TokenFlow 2510.02758:
//! burst-time decisions must be made ahead of the scheduler):
//!
//! - **scale-out** is *predictive but not free*: a requested replica
//!   only starts serving after a configurable cold-start delay
//!   (weights loading, KV allocation), so the planner works off the
//!   rate estimate rather than waiting for queues to form;
//! - **scale-in** is *reluctant*: the target must sit at or below the
//!   live count for a hysteresis hold before any replica is retired,
//!   so a gap between bursts does not thrash replicas down and
//!   immediately pay the cold start again;
//! - **memory pressure overrides**: mean KV utilization above the high
//!   watermark forces one extra replica regardless of the rate signal
//!   (long-context traffic saturates memory before it saturates rate).
//!
//! The autoscaler only plans; the [`super::Gateway`] applies the plan
//! through [`super::GatewayTarget::scale_out`] / `scale_in`, and the
//! cluster charges **replica-seconds** (commission → decommission) as
//! the run's cost metric.
//!
//! ```
//! use andes::gateway::{AutoscaleConfig, PredictiveAutoscaler};
//!
//! let auto = PredictiveAutoscaler::new(AutoscaleConfig {
//!     enabled: true,
//!     min_replicas: 1,
//!     max_replicas: 4,
//!     replica_capacity: 2.0,
//!     target_utilization: 1.0,
//!     ..AutoscaleConfig::default()
//! });
//! assert_eq!(auto.target_replicas(0.0), 1); // min clamp
//! assert_eq!(auto.target_replicas(5.0), 3); // ceil(5 / 2)
//! assert_eq!(auto.target_replicas(50.0), 4); // max clamp
//! ```

use std::collections::VecDeque;

use super::admission::ReplicaState;

/// Autoscaler configuration.
#[derive(Debug, Clone)]
pub struct AutoscaleConfig {
    pub enabled: bool,
    /// Never retire below this many routable replicas.
    pub min_replicas: usize,
    /// Never provision beyond this many replicas.
    pub max_replicas: usize,
    /// Sustainable per-replica request rate (req/s) — typically the
    /// analytic capacity estimate of one replica.
    pub replica_capacity: f64,
    /// Fraction of `replica_capacity` to plan for; values below 1
    /// over-provision (headroom for estimate error and bursts).
    pub target_utilization: f64,
    /// Scale-out lead time: a requested replica serves only after this
    /// cold-start delay (s).
    pub cold_start_secs: f64,
    /// Scale-in hysteresis: the target must stay at or below the live
    /// count for this long before a replica is retired (s).
    pub scale_in_hold_secs: f64,
    /// Mean KV utilization above which one extra replica is requested
    /// regardless of the rate estimate.
    pub kv_high_watermark: f64,
    /// Minimum time between target re-evaluations (s).
    pub eval_interval_secs: f64,
}

impl Default for AutoscaleConfig {
    fn default() -> Self {
        AutoscaleConfig {
            enabled: false,
            min_replicas: 1,
            max_replicas: 4,
            replica_capacity: 1.0,
            target_utilization: 0.8,
            cold_start_secs: 15.0,
            scale_in_hold_secs: 30.0,
            kv_high_watermark: 0.9,
            eval_interval_secs: 1.0,
        }
    }
}

/// What the gateway should do right now: commission replicas whose cold
/// start completed, and/or begin retiring live ones.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ScalePlan {
    pub commission: usize,
    pub retire: usize,
}

impl ScalePlan {
    pub fn is_noop(&self) -> bool {
        self.commission == 0 && self.retire == 0
    }
}

/// The predictive autoscaler. Pure planning state — it never touches
/// the cluster itself.
#[derive(Debug, Clone)]
pub struct PredictiveAutoscaler {
    cfg: AutoscaleConfig,
    /// Ready times of requested-but-still-cold replicas, oldest first.
    pending: VecDeque<f64>,
    /// Since when the target has continuously been below the live count.
    below_since: Option<f64>,
    last_eval: Option<f64>,
    scale_out_requests: u64,
    retirements: u64,
    /// Latest `t` ever passed to [`Self::evaluate`] — watchdog state
    /// for the clock-drift regression counter below.
    last_t: f64,
    /// Times `evaluate` observed `t` run backwards. The gateway clamps
    /// sweep times to the serving-tier clock precisely so this stays 0
    /// (see `tests/calendar.rs`); a nonzero count means a caller let
    /// the defer sweep and the evaluation tick disagree on "now".
    time_regressions: u64,
}

impl PredictiveAutoscaler {
    pub fn new(cfg: AutoscaleConfig) -> Self {
        assert!(cfg.min_replicas >= 1, "min_replicas must be >= 1");
        assert!(
            cfg.max_replicas >= cfg.min_replicas,
            "max_replicas must be >= min_replicas"
        );
        assert!(cfg.replica_capacity > 0.0, "replica_capacity must be > 0");
        assert!(cfg.target_utilization > 0.0, "target_utilization must be > 0");
        PredictiveAutoscaler {
            cfg,
            pending: VecDeque::new(),
            below_since: None,
            last_eval: None,
            scale_out_requests: 0,
            retirements: 0,
            last_t: f64::NEG_INFINITY,
            time_regressions: 0,
        }
    }

    pub fn config(&self) -> &AutoscaleConfig {
        &self.cfg
    }

    /// Replicas requested but still inside their cold-start window.
    pub fn pending_replicas(&self) -> usize {
        self.pending.len()
    }

    /// Lifetime scale-out requests (includes still-pending ones).
    pub fn scale_out_requests(&self) -> u64 {
        self.scale_out_requests
    }

    /// Lifetime retirements planned.
    pub fn retirements(&self) -> u64 {
        self.retirements
    }

    /// Times the planner observed its clock run backwards (should stay
    /// 0 — see the field docs).
    pub fn time_regressions(&self) -> u64 {
        self.time_regressions
    }

    /// The next time the planner's state changes on its own — a pending
    /// replica's cold start completing, or the scale-in hold expiring —
    /// so the gateway can sweep at that instant instead of waiting for
    /// the next arrival (idle gaps would otherwise inflate
    /// replica-seconds, the cost metric).
    pub fn next_event(&self) -> Option<f64> {
        if !self.cfg.enabled {
            return None;
        }
        let ready = self.pending.front().copied();
        // A hold expiry only takes effect at an evaluation point, so
        // never report it earlier than the next allowed evaluation
        // (otherwise a sweep at the raw expiry would be gated off and
        // the caller would spin on the same instant).
        let hold = self.below_since.map(|since| {
            let ev = since + self.cfg.scale_in_hold_secs;
            match self.last_eval {
                Some(last) => ev.max(last + self.cfg.eval_interval_secs),
                None => ev,
            }
        });
        match (ready, hold) {
            (Some(a), Some(b)) => Some(a.min(b)),
            (Some(a), None) => Some(a),
            (None, Some(b)) => Some(b),
            (None, None) => None,
        }
    }

    /// Replica count needed to serve `rate` at the planned utilization,
    /// clamped to [min_replicas, max_replicas].
    pub fn target_replicas(&self, rate: f64) -> usize {
        let per = self.cfg.replica_capacity * self.cfg.target_utilization;
        let need = (rate.max(0.0) / per).ceil() as usize;
        need.clamp(self.cfg.min_replicas, self.cfg.max_replicas)
    }

    /// Re-plan at time `t` given the windowed arrival-rate estimate and
    /// the live (routable) replica snapshots. `live` is the current
    /// routable replica count. Returns the actions due *now*.
    pub fn evaluate(
        &mut self,
        t: f64,
        rate: f64,
        states: &[ReplicaState],
        live: usize,
    ) -> ScalePlan {
        let mut plan = ScalePlan::default();
        if !self.cfg.enabled {
            return plan;
        }
        if t < self.last_t {
            self.time_regressions += 1;
        }
        self.last_t = t;
        // Commission every replica whose cold start has completed —
        // this happens on every call, not just at eval intervals.
        while self.pending.front().is_some_and(|&ready| ready <= t) {
            self.pending.pop_front();
            plan.commission += 1;
        }
        let live = live + plan.commission;
        if self
            .last_eval
            .is_some_and(|last| t - last < self.cfg.eval_interval_secs)
        {
            return plan;
        }
        self.last_eval = Some(t);

        let mut target = self.target_replicas(rate);
        if !states.is_empty() {
            let mean_util = states.iter().map(|s| s.kv_utilization()).sum::<f64>()
                / states.len() as f64;
            if mean_util > self.cfg.kv_high_watermark {
                target = target.max((live + 1).min(self.cfg.max_replicas));
            }
        }

        let provisioned = live + self.pending.len();
        if target > provisioned {
            for _ in provisioned..target {
                self.pending.push_back(t + self.cfg.cold_start_secs);
                self.scale_out_requests += 1;
            }
            self.below_since = None;
        } else if target < provisioned {
            // Abort still-cold replicas first: they are free to cancel.
            // (They stay counted in `scale_out_requests` — aborted cold
            // starts are real planner activity.)
            while live + self.pending.len() > target.max(live) && !self.pending.is_empty()
            {
                self.pending.pop_back();
            }
            if target < live {
                match self.below_since {
                    None => self.below_since = Some(t),
                    Some(since) if t - since >= self.cfg.scale_in_hold_secs => {
                        plan.retire = live - target;
                        self.retirements += plan.retire as u64;
                        // Further scale-in requires a fresh hold.
                        self.below_since = Some(t);
                    }
                    Some(_) => {}
                }
            } else {
                self.below_since = None;
            }
        } else {
            self.below_since = None;
        }
        plan
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn state(free: usize, cap: usize) -> ReplicaState {
        ReplicaState {
            active_requests: 4,
            kv_free_tokens: free,
            kv_capacity_tokens: cap,
            est_request_tds: 6.0,
        }
    }

    fn cfg() -> AutoscaleConfig {
        AutoscaleConfig {
            enabled: true,
            min_replicas: 1,
            max_replicas: 4,
            replica_capacity: 2.0,
            target_utilization: 1.0,
            cold_start_secs: 10.0,
            scale_in_hold_secs: 30.0,
            kv_high_watermark: 0.9,
            eval_interval_secs: 1.0,
        }
    }

    #[test]
    fn target_tracks_rate_with_clamps() {
        let a = PredictiveAutoscaler::new(cfg());
        assert_eq!(a.target_replicas(0.0), 1); // min clamp
        assert_eq!(a.target_replicas(1.9), 1);
        assert_eq!(a.target_replicas(2.1), 2);
        assert_eq!(a.target_replicas(6.0), 3);
        assert_eq!(a.target_replicas(50.0), 4); // max clamp
    }

    #[test]
    fn disabled_autoscaler_is_noop() {
        let mut a = PredictiveAutoscaler::new(AutoscaleConfig::default());
        let healthy = [state(60_000, 70_000)];
        for t in 1..100 {
            assert!(a.evaluate(t as f64, 50.0, &healthy, 1).is_noop());
        }
    }

    #[test]
    fn cold_start_delays_commissioning() {
        let mut a = PredictiveAutoscaler::new(cfg());
        let healthy = [state(60_000, 70_000)];
        // Rate needs 3 replicas; only 1 live → 2 requested at t=0.
        assert!(a.evaluate(0.0, 6.0, &healthy, 1).is_noop());
        assert_eq!(a.pending_replicas(), 2);
        // Still cold at t=9.9.
        assert!(a.evaluate(9.9, 6.0, &healthy, 1).is_noop());
        // Ready at t=10: both commission together.
        let plan = a.evaluate(10.0, 6.0, &healthy, 1);
        assert_eq!(plan.commission, 2);
        assert_eq!(a.pending_replicas(), 0);
    }

    #[test]
    fn scale_in_waits_for_hold_then_retires() {
        let mut a = PredictiveAutoscaler::new(cfg());
        let healthy = [state(60_000, 70_000)];
        // Load vanished with 3 live replicas: target 1, but the hold
        // (30 s) must elapse before anything retires.
        assert!(a.evaluate(0.0, 0.5, &healthy, 3).is_noop());
        assert!(a.evaluate(15.0, 0.5, &healthy, 3).is_noop());
        let plan = a.evaluate(31.0, 0.5, &healthy, 3);
        assert_eq!(plan.retire, 2);
        assert_eq!(a.retirements(), 2);
    }

    #[test]
    fn burst_gap_shorter_than_hold_does_not_thrash() {
        let mut a = PredictiveAutoscaler::new(cfg());
        let healthy = [state(60_000, 70_000)];
        // 2 live, rate drops for 20 s (< hold 30 s) then recovers:
        // nothing retires and nothing new is requested.
        for t in 0..20 {
            assert!(a.evaluate(t as f64, 0.5, &healthy, 2).is_noop(), "t={t}");
        }
        assert!(a.evaluate(20.0, 4.0, &healthy, 2).is_noop());
        assert_eq!(a.retirements(), 0);
        assert_eq!(a.pending_replicas(), 0);
        // And the recovery reset the hold: another short dip still
        // retires nothing.
        assert!(a.evaluate(35.0, 0.5, &healthy, 2).is_noop());
        assert!(a.evaluate(45.0, 0.5, &healthy, 2).is_noop());
    }

    #[test]
    fn rate_drop_cancels_cold_replicas_first() {
        let mut a = PredictiveAutoscaler::new(cfg());
        let healthy = [state(60_000, 70_000)];
        assert!(a.evaluate(0.0, 8.0, &healthy, 1).is_noop()); // wants 4 → 3 pending
        assert_eq!(a.pending_replicas(), 3);
        // Demand collapses before the cold start completes: the pending
        // requests are aborted without ever serving.
        assert!(a.evaluate(2.0, 0.5, &healthy, 1).is_noop());
        assert_eq!(a.pending_replicas(), 0);
        assert!(a.evaluate(12.0, 0.5, &healthy, 1).is_noop());
        assert_eq!(a.retirements(), 0);
    }

    #[test]
    fn kv_pressure_forces_scale_out() {
        let mut a = PredictiveAutoscaler::new(cfg());
        // Rate alone says 1 replica, but KV is 95% full.
        let pressured = [state(3_500, 70_000)];
        assert!(a.evaluate(0.0, 1.0, &pressured, 1).is_noop());
        assert_eq!(a.pending_replicas(), 1);
    }

    #[test]
    fn eval_interval_rate_limits_planning() {
        let mut a = PredictiveAutoscaler::new(cfg());
        let healthy = [state(60_000, 70_000)];
        assert!(a.evaluate(0.0, 6.0, &healthy, 1).is_noop());
        let before = a.pending_replicas();
        // Calls inside the interval do not re-plan (no double-request).
        for i in 1..9 {
            a.evaluate(0.1 * i as f64, 20.0, &healthy, 1);
        }
        assert_eq!(a.pending_replicas(), before);
    }

    #[test]
    fn next_event_reports_cold_starts_and_hold_expiry() {
        let mut a = PredictiveAutoscaler::new(cfg());
        let healthy = [state(60_000, 70_000)];
        assert_eq!(a.next_event(), None);
        // Scale-out request → next event is the cold-start completion.
        a.evaluate(0.0, 6.0, &healthy, 1);
        assert_eq!(a.next_event(), Some(10.0));
        a.evaluate(10.0, 6.0, &healthy, 1); // commissions
        assert_eq!(a.next_event(), None);
        // Demand vanishes with 3 live → next event is the hold expiry.
        a.evaluate(12.0, 0.5, &healthy, 3);
        assert_eq!(a.next_event(), Some(42.0));
        // Sweeping at the reported instant actually retires.
        let plan = a.evaluate(42.0, 0.5, &healthy, 3);
        assert_eq!(plan.retire, 2);
    }

    #[test]
    #[should_panic]
    fn rejects_inverted_bounds() {
        PredictiveAutoscaler::new(AutoscaleConfig {
            min_replicas: 4,
            max_replicas: 2,
            ..AutoscaleConfig::default()
        });
    }
}
