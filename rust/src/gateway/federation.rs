//! Multi-gateway federation: N admission front doors over one cluster.
//!
//! A single [`super::Gateway`] is a serialization point — at
//! "millions of users" scale the front door itself must scale out. This
//! module runs N gateway instances ([`FederationNode`]s) in front of
//! one shared serving tier, *without a central admission lock*:
//!
//! - each node owns its own [`AdmissionController`] (with its own
//!   hysteresis latch), [`SurgeDetector`] (baseline scaled to the
//!   node's 1/N share of arrivals), and weight-ordered defer queue;
//! - nodes exchange **state snapshots** ([`StateSnapshot`]: per-replica
//!   active counts, KV utilization, fair-share speed estimates) every
//!   `sync_interval_secs`. Between syncs a node layers its **local
//!   admission ledger** — the expected KV context of everything it
//!   admitted since its snapshot — on top of the snapshot
//!   ([`merge_snapshot`]), so its view stays optimistic-but-bounded
//!   rather than frozen;
//! - a node whose snapshot ages past `staleness_bound_secs` forces an
//!   individual refresh instead of acting on arbitrarily stale state
//!   (the TokenFlow burst result: admission on stale load state
//!   degrades sharply).
//!
//! Decisions made on stale views can diverge across nodes; the
//! **disagreement probe** asks every peer what it would have decided
//! for each arrival (via the latch-preserving
//! [`AdmissionController::preview`]) and reports the disagreement rate
//! — the `ext-federation` experiment's convergence metric. See
//! DESIGN.md §9 for the protocol and the admit/defer/reject decision
//! table under disagreement.
//!
//! The federated path fronts a static (or externally scaled) cluster:
//! the predictive autoscaler and spill tier remain single-gateway
//! features (`super::Gateway`), since both need one owner for the
//! scale/replay decisions.
//!
//! ```
//! use andes::cluster::{Cluster, RoutingPolicy};
//! use andes::config::SchedulerConfig;
//! use andes::coordinator::engine::EngineConfig;
//! use andes::gateway::{FederatedGateway, FederationConfig, GatewayConfig};
//! use andes::model::gpu::a100_4x;
//! use andes::model::latency::LatencyModel;
//! use andes::model::llm::opt_66b;
//! use andes::qoe::spec::QoeSpec;
//! use andes::workload::RequestSpec;
//!
//! let latency = LatencyModel::for_deployment(&opt_66b(), &a100_4x());
//! let cluster = Cluster::new(
//!     2,
//!     EngineConfig::default(),
//!     latency,
//!     &SchedulerConfig::Fcfs,
//!     RoutingPolicy::LeastLoaded,
//! );
//! let fed = FederationConfig { gateways: 2, ..FederationConfig::default() };
//! let mut gw = FederatedGateway::new(cluster, GatewayConfig::default(), fed);
//! let trace: Vec<RequestSpec> = (0..4)
//!     .map(|i| RequestSpec {
//!         id: i,
//!         arrival: 0.2 * (i + 1) as f64,
//!         prompt_tokens: 100,
//!         output_tokens: 20,
//!         qoe: QoeSpec::new(1.0, 4.8),
//!         session: None,
//!     })
//!     .collect();
//! let res = gw.run_trace(trace).unwrap();
//! assert_eq!(res.served.len() + res.rejections.len(), 4);
//! ```

use std::collections::VecDeque;

use anyhow::Result;

use crate::coordinator::calendar::{EventCalendar, EventKind, WakeupToken};
use crate::coordinator::metrics::Metrics;
use crate::workload::RequestSpec;

use super::admission::{AdmissionController, AdmissionDecision, RejectReason, ReplicaState};
use super::surge::{LoadMode, SurgeDetector};
use super::{
    earliest_deadline, enqueue_by_weight, served_outcome, DeferredRequest, GatewayConfig,
    GatewayTarget, Rejection, ServedRequest, SubmitOutcome,
};

/// Federation configuration.
#[derive(Debug, Clone)]
pub struct FederationConfig {
    /// Number of gateway instances fronting the cluster (1 = the plain
    /// single-gateway path).
    pub gateways: usize,
    /// Period between state-snapshot exchanges (s). Shorter syncs keep
    /// node views closer to ground truth at higher exchange cost.
    pub sync_interval_secs: f64,
    /// Maximum snapshot age a node will act on before forcing its own
    /// refresh (s). Bounds how wrong a node's view can be when the
    /// exchange period is long or a sync is missed.
    pub staleness_bound_secs: f64,
}

impl Default for FederationConfig {
    fn default() -> Self {
        FederationConfig {
            gateways: 1,
            sync_interval_secs: 0.25,
            staleness_bound_secs: 2.0,
        }
    }
}

/// One node's view of the serving tier at a sync point — the state
/// gateways exchange.
#[derive(Debug, Clone)]
pub struct StateSnapshot {
    /// When the snapshot was taken.
    pub taken_at: f64,
    /// Per-replica state as of `taken_at`.
    pub replicas: Vec<ReplicaState>,
}

/// Fold a node's local admission ledger into its last snapshot: each
/// locally admitted request claims its expected KV context from the
/// replica with the most free KV (mirroring where routing would have
/// placed it), bumps that replica's active count, and shrinks its
/// fair-share speed estimate accordingly. This is the optimistic view a
/// node decides on until the next snapshot exchange; peers' admissions
/// stay invisible until then, which is exactly the divergence the
/// staleness bound caps.
pub fn merge_snapshot(snapshot: &[ReplicaState], local_admits: &[usize]) -> Vec<ReplicaState> {
    let mut view: Vec<ReplicaState> = snapshot.to_vec();
    for &context_tokens in local_admits {
        if let Some(r) = view.iter_mut().max_by_key(|r| r.kv_free_tokens) {
            r.kv_free_tokens = r.kv_free_tokens.saturating_sub(context_tokens);
            // est_request_tds is the fair share across (active + 1)
            // requests; one more admission re-splits it.
            let a = r.active_requests as f64;
            r.est_request_tds *= (a + 1.0) / (a + 2.0);
            r.active_requests += 1;
        }
    }
    view
}

/// One gateway instance inside the federation: its own admission
/// controller, surge detector, defer queue, snapshot, and local ledger.
pub struct FederationNode {
    admission: AdmissionController,
    surge: SurgeDetector,
    snapshot: StateSnapshot,
    /// Expected context tokens (prompt + expected output) of requests
    /// this node admitted since its snapshot was taken.
    local_admits: Vec<usize>,
    queue: VecDeque<DeferredRequest>,
}

impl FederationNode {
    /// The replica states this node currently believes in.
    fn view(&self) -> Vec<ReplicaState> {
        merge_snapshot(&self.snapshot.replicas, &self.local_admits)
    }

    fn refresh(&mut self, replicas: Vec<ReplicaState>, t: f64) {
        self.snapshot = StateSnapshot { taken_at: t, replicas };
        self.local_admits.clear();
    }
}

/// Lifetime counters across the federation.
#[derive(Debug, Clone, Default)]
pub struct FederationStats {
    pub arrivals: usize,
    pub admitted: usize,
    /// Requests that passed through some node's defer queue.
    pub deferred: usize,
    pub rejected: usize,
    /// Full snapshot exchanges (all nodes refreshed together).
    pub syncs: u64,
    /// Individual refreshes forced by the staleness bound.
    pub forced_refreshes: u64,
    /// Arrivals where at least one peer's would-be decision class
    /// (admit / defer / reject) differed from the owning node's.
    pub disagreements: usize,
    /// Arrivals probed for disagreement (every admission-controlled
    /// arrival when `gateways > 1`).
    pub probed: usize,
}

impl FederationStats {
    /// Fraction of probed arrivals on which the nodes disagreed.
    pub fn disagreement_rate(&self) -> f64 {
        if self.probed == 0 {
            return 0.0;
        }
        self.disagreements as f64 / self.probed as f64
    }
}

/// Result of a full federated trace run.
#[derive(Debug)]
pub struct FederationRunResult {
    pub per_replica: Vec<Metrics>,
    pub served: Vec<ServedRequest>,
    pub rejections: Vec<Rejection>,
    pub stats: FederationStats,
    pub replica_seconds: f64,
}

impl FederationRunResult {
    pub fn served_count(&self) -> usize {
        self.served.len()
    }

    /// Mean final QoE over served requests (post-pacing).
    pub fn mean_served_qoe(&self) -> f64 {
        if self.served.is_empty() {
            return 0.0;
        }
        self.served.iter().map(|s| s.paced_qoe).sum::<f64>() / self.served.len() as f64
    }

    /// Mean QoE over *all* arrivals, counting each rejection as QoE 0.
    pub fn mean_qoe_incl_rejects(&self) -> f64 {
        let n = self.served.len() + self.rejections.len();
        if n == 0 {
            return 0.0;
        }
        self.served.iter().map(|s| s.paced_qoe).sum::<f64>() / n as f64
    }

    pub fn rejected_fraction(&self) -> f64 {
        let n = self.served.len() + self.rejections.len();
        if n == 0 {
            return 0.0;
        }
        self.rejections.len() as f64 / n as f64
    }
}

/// N federated gateway instances over one shared serving tier.
pub struct FederatedGateway<T: GatewayTarget> {
    cfg: GatewayConfig,
    fed: FederationConfig,
    target: T,
    nodes: Vec<FederationNode>,
    /// Round-robin dispatch cursor (models a tier-blind L4 balancer in
    /// front of the gateways).
    next_node: usize,
    last_sync: f64,
    /// Event-time index (DESIGN.md §14): one DeferDeadline wakeup per
    /// queued request (payload = owning node) plus at most one
    /// FederationSync wakeup mirroring `last_sync + sync_interval`.
    /// Unused on the legacy path.
    calendar: EventCalendar,
    /// Token for the single registered FederationSync wakeup, if any.
    sync_wakeup: Option<WakeupToken>,
    rejections: Vec<Rejection>,
    stats: FederationStats,
}

impl<T: GatewayTarget> FederatedGateway<T> {
    pub fn new(target: T, cfg: GatewayConfig, fed: FederationConfig) -> Self {
        assert!(fed.gateways >= 1, "federation needs at least one gateway");
        assert!(fed.sync_interval_secs > 0.0, "sync interval must be positive");
        assert!(fed.staleness_bound_secs >= 0.0, "staleness bound must be non-negative");
        let n = fed.gateways;
        let t0 = target.now();
        let states = target.replica_states();
        // Each node sees ~1/N of the arrivals, so its surge baseline is
        // its fair share of the cluster's sustainable rate.
        let mut surge_cfg = cfg.surge.clone();
        surge_cfg.baseline_rate = (surge_cfg.baseline_rate / n as f64).max(1e-9);
        let nodes = (0..n)
            .map(|_| FederationNode {
                admission: AdmissionController::new(cfg.admission.clone()),
                surge: SurgeDetector::new(surge_cfg.clone()),
                snapshot: StateSnapshot { taken_at: t0, replicas: states.clone() },
                local_admits: Vec::new(),
                queue: VecDeque::new(),
            })
            .collect();
        let mut fgw = FederatedGateway {
            cfg,
            fed,
            target,
            nodes,
            next_node: 0,
            last_sync: t0,
            calendar: EventCalendar::new(),
            sync_wakeup: None,
            rejections: Vec::new(),
            stats: FederationStats::default(),
        };
        fgw.reconcile_sync_wakeup();
        fgw
    }

    pub fn target(&self) -> &T {
        &self.target
    }

    pub fn stats(&self) -> &FederationStats {
        &self.stats
    }

    pub fn rejections(&self) -> &[Rejection] {
        &self.rejections
    }

    pub fn num_gateways(&self) -> usize {
        self.nodes.len()
    }

    /// Refresh every node from ground truth — in the simulation all
    /// nodes front the same target, so "exchange and merge everyone's
    /// deltas" and "read the shared tier" converge to the same state.
    fn sync_all(&mut self, t: f64) {
        let states = self.target.replica_states();
        for node in &mut self.nodes {
            node.refresh(states.clone(), t);
        }
        self.last_sync = t;
        self.stats.syncs += 1;
        self.reconcile_sync_wakeup();
    }

    /// Re-point the calendar's single FederationSync wakeup at
    /// `last_sync + sync_interval`. `last_sync` only changes in
    /// [`Self::sync_all`] (forced per-node refreshes leave the exchange
    /// schedule alone), so reconciling there keeps the calendar index
    /// exactly equal to the legacy path's live computation.
    fn reconcile_sync_wakeup(&mut self) {
        if self.cfg.legacy_stepping {
            return;
        }
        if let Some(w) = self.sync_wakeup.take() {
            self.calendar.cancel(w);
        }
        if self.nodes.len() > 1 {
            self.sync_wakeup = Some(self.calendar.register(
                self.last_sync + self.fed.sync_interval_secs,
                EventKind::FederationSync,
                0,
            ));
        }
    }

    /// Run the snapshot-exchange protocol at time `t`: a full exchange
    /// when the sync interval elapsed, else individual refreshes for
    /// nodes past the staleness bound. A single node needs neither —
    /// it reads ground truth on every decision (see [`Self::node_view`]).
    fn maybe_sync(&mut self, t: f64) {
        if self.nodes.len() <= 1 {
            return;
        }
        if t - self.last_sync + 1e-9 >= self.fed.sync_interval_secs {
            self.sync_all(t);
            return;
        }
        for node in self.nodes.iter_mut() {
            if t - node.snapshot.taken_at > self.fed.staleness_bound_secs {
                node.refresh(self.target.replica_states(), t);
                self.stats.forced_refreshes += 1;
            }
        }
    }

    /// The replica states node `i` decides on: its snapshot plus local
    /// ledger when federated, the target's ground truth when it is the
    /// only gateway (a lone node has nobody to be stale against, and
    /// must reproduce [`super::Gateway`]'s decisions exactly).
    fn node_view(&mut self, i: usize) -> Vec<ReplicaState> {
        if self.nodes.len() == 1 {
            let states = self.target.replica_states();
            let now = self.target.now();
            self.nodes[i].refresh(states.clone(), now);
            states
        } else {
            self.nodes[i].view()
        }
    }

    /// Earliest defer deadline across every node's queue. The calendar
    /// query and the legacy per-node scans compute the same value
    /// (`enqueued_at + max_defer_wait`), so the two paths agree bit for
    /// bit.
    fn next_defer_deadline(&self) -> Option<f64> {
        if self.cfg.legacy_stepping {
            self.nodes
                .iter()
                .filter_map(|n| {
                    earliest_deadline(&n.queue, self.cfg.admission.max_defer_wait)
                })
                .min_by(f64::total_cmp)
        } else {
            self.calendar.next_time_of(EventKind::DeferDeadline)
        }
    }

    /// Next instant before `t` at which federation state changes on its
    /// own: a defer deadline, or (with real federation) a snapshot
    /// exchange falling due.
    fn next_event(&self, t: f64) -> Option<f64> {
        let sync = if self.cfg.legacy_stepping {
            (self.nodes.len() > 1).then_some(self.last_sync + self.fed.sync_interval_secs)
        } else {
            self.calendar.next_time_of(EventKind::FederationSync)
        };
        let ev = match (self.next_defer_deadline(), sync) {
            (Some(a), Some(b)) => a.min(b),
            (Some(a), None) | (None, Some(a)) => a,
            (None, None) => return None,
        };
        (ev < t).then_some(ev)
    }

    /// Advance the serving tier to `t`, sweeping every defer deadline
    /// and sync point inside the gap at its own due time (the same
    /// event-stepping discipline as [`super::Gateway::submit`] — never
    /// arrival-driven).
    fn advance_world(&mut self, t: f64) -> Result<()> {
        let mut last_ev = f64::NEG_INFINITY;
        while let Some(ev) = self.next_event(t) {
            if ev <= last_ev {
                // Defensive: same-instant deadlines are all handled by
                // one flush; every sweep must advance time.
                break;
            }
            last_ev = ev;
            self.target.advance_to(ev)?;
            self.maybe_sync(ev);
            self.flush_all(ev)?;
        }
        self.target.advance_to(t)?;
        self.maybe_sync(t);
        Ok(())
    }

    /// Submit a request admitted by node `i` to the shared tier and
    /// record it in the node's local ledger.
    fn admit_to_target(&mut self, i: usize, spec: RequestSpec) -> Result<()> {
        let policy = if self.cfg.admission_enabled
            && self.nodes[i].surge.mode() == LoadMode::Surge
        {
            self.cfg.surge_routing
        } else {
            None
        };
        let context = spec.prompt_tokens + self.cfg.admission.expected_output_tokens;
        self.target.submit_routed(spec, policy)?;
        self.nodes[i].local_admits.push(context);
        self.stats.admitted += 1;
        Ok(())
    }

    fn reject(&mut self, spec: RequestSpec, t: f64, reason: RejectReason) {
        self.rejections.push(Rejection { id: spec.id, time: t, reason });
        self.stats.rejected += 1;
    }

    /// Re-examine node `i`'s defer queue at time `t` — the same
    /// priority-ordered sweep as [`super::Gateway`]'s, against the
    /// node's (possibly stale) view.
    fn flush_node(&mut self, i: usize, t: f64) -> Result<()> {
        loop {
            if self.nodes[i].queue.is_empty() {
                return Ok(());
            }
            let view = self.node_view(i);
            let decision = {
                let node = &mut self.nodes[i];
                let (prompt, qoe) = match node.queue.front() {
                    Some(d) => (d.spec.prompt_tokens, d.spec.qoe),
                    None => return Ok(()),
                };
                let mode = node.surge.mode();
                let depth = node.queue.len().saturating_sub(1);
                node.admission.decide(prompt, &qoe, &view, mode, depth)
            };
            if decision == AdmissionDecision::Admit {
                // lint:allow(D6, front() returned Some when forming the decision)
                let d = self.nodes[i].queue.pop_front().unwrap();
                if let Some(w) = d.wakeup {
                    self.calendar.cancel(w);
                }
                self.admit_to_target(i, d.spec)?;
                continue;
            }
            let due_idx = {
                let node = &self.nodes[i];
                (0..node.queue.len()).find(|&k| {
                    t - node.queue[k].enqueued_at + 1e-9
                        >= self.cfg.admission.max_defer_wait
                })
            };
            match due_idx {
                Some(0) => {
                    // The decide above was the front's final chance.
                    // lint:allow(D6, due_idx == Some(0) proves the queue is non-empty)
                    let d = self.nodes[i].queue.pop_front().unwrap();
                    if let Some(w) = d.wakeup {
                        self.calendar.cancel(w);
                    }
                    let waited = t - d.enqueued_at;
                    self.reject(d.spec, t, RejectReason::DeferTimeout { waited });
                }
                Some(k) => {
                    let view = self.node_view(i);
                    let d2 = {
                        let node = &mut self.nodes[i];
                        let (p2, q2) =
                            (node.queue[k].spec.prompt_tokens, node.queue[k].spec.qoe);
                        let mode = node.surge.mode();
                        let depth = node.queue.len().saturating_sub(1);
                        node.admission.decide(p2, &q2, &view, mode, depth)
                    };
                    // lint:allow(D6, k indexes into the queue per the find() above)
                    let d = self.nodes[i].queue.remove(k).unwrap();
                    if let Some(w) = d.wakeup {
                        self.calendar.cancel(w);
                    }
                    if d2 == AdmissionDecision::Admit {
                        self.admit_to_target(i, d.spec)?;
                    } else {
                        let waited = t - d.enqueued_at;
                        self.reject(d.spec, t, RejectReason::DeferTimeout { waited });
                    }
                }
                None => return Ok(()),
            }
        }
    }

    fn flush_all(&mut self, t: f64) -> Result<()> {
        for i in 0..self.nodes.len() {
            self.flush_node(i, t)?;
        }
        Ok(())
    }

    /// Probe every node's would-be decision for this arrival on its own
    /// view (latch-preserving), recording whether the federation agrees.
    fn probe_disagreement(&mut self, spec: &RequestSpec) {
        if self.nodes.len() <= 1 {
            return;
        }
        self.stats.probed += 1;
        let mut first: Option<u8> = None;
        let mut disagreed = false;
        for node in &self.nodes {
            let view = node.view();
            let d = node.admission.preview(
                spec.prompt_tokens,
                &spec.qoe,
                &view,
                node.surge.mode(),
                node.queue.len(),
            );
            let class = match d {
                AdmissionDecision::Admit => 0u8,
                AdmissionDecision::Defer => 1,
                AdmissionDecision::Reject(_) => 2,
            };
            match first {
                None => first = Some(class),
                Some(c) if c != class => disagreed = true,
                Some(_) => {}
            }
        }
        if disagreed {
            self.stats.disagreements += 1;
        }
    }

    /// Handle one arriving request: advance the world to its arrival
    /// (sweeping defer deadlines and sync points on the way), dispatch
    /// it round-robin to a node, and let that node decide on its local
    /// view.
    pub fn submit(&mut self, spec: RequestSpec) -> Result<SubmitOutcome> {
        let t = spec.arrival;
        self.advance_world(t)?;
        self.stats.arrivals += 1;
        let owner = self.next_node % self.nodes.len();
        self.next_node += 1;
        self.nodes[owner].surge.observe(t);
        self.flush_node(owner, t)?;
        if !self.cfg.admission_enabled {
            self.target.submit_routed(spec, None)?;
            self.stats.admitted += 1;
            return Ok(SubmitOutcome::Admitted);
        }
        self.probe_disagreement(&spec);
        let view = self.node_view(owner);
        let decision = {
            let node = &mut self.nodes[owner];
            let mode = node.surge.mode();
            let depth = node.queue.len();
            node.admission.decide(spec.prompt_tokens, &spec.qoe, &view, mode, depth)
        };
        match decision {
            AdmissionDecision::Admit => {
                self.admit_to_target(owner, spec)?;
                Ok(SubmitOutcome::Admitted)
            }
            AdmissionDecision::Defer => {
                let weight = self.cfg.admission.tier_weights.weight_for(&spec.qoe);
                let wakeup = (!self.cfg.legacy_stepping).then(|| {
                    self.calendar.register(
                        t + self.cfg.admission.max_defer_wait,
                        EventKind::DeferDeadline,
                        owner as u64,
                    )
                });
                enqueue_by_weight(
                    &mut self.nodes[owner].queue,
                    DeferredRequest { spec, enqueued_at: t, weight, wakeup },
                );
                self.stats.deferred += 1;
                Ok(SubmitOutcome::Deferred)
            }
            AdmissionDecision::Reject(reason) => {
                self.reject(spec, t, reason);
                Ok(SubmitOutcome::Rejected(reason))
            }
        }
    }

    /// Drain the serving tier, resolving every node's defer queue at
    /// its own deadlines, then post-process delivery.
    pub fn finish(&mut self) -> Result<FederationRunResult> {
        while self.nodes.iter().any(|n| !n.queue.is_empty()) {
            // lint:allow(D6, the while condition guarantees a non-empty queue)
            let deadline = self.next_defer_deadline().expect("non-empty queue");
            if self.target.now() + 1e-9 >= deadline {
                // Due now (the clock may have overshot by at most one
                // engine iteration): account the expiry at the deadline
                // itself so `waited` stays exact.
                self.maybe_sync(deadline);
                self.flush_all(deadline)?;
                continue;
            }
            match self.target.step_once()? {
                Some(stepped) => {
                    let ev = stepped.min(deadline);
                    self.maybe_sync(ev);
                    self.flush_all(ev)?;
                }
                None => {
                    self.target.advance_to(deadline)?;
                    self.maybe_sync(deadline);
                    self.flush_all(deadline)?;
                }
            }
        }
        let per_replica = self.target.drain()?;
        let replica_seconds = self.target.replica_seconds(self.target.now());
        let mut served = Vec::new();
        for m in &per_replica {
            for r in &m.requests {
                served.push(served_outcome(r, &self.cfg));
            }
        }
        Ok(FederationRunResult {
            per_replica,
            served,
            rejections: self.rejections.clone(),
            stats: self.stats.clone(),
            replica_seconds,
        })
    }

    /// Run a whole trace through the federation and finish. Non-finite
    /// arrivals are clamped to the trace origin, as in
    /// [`super::Gateway::run_trace`].
    pub fn run_trace(&mut self, mut trace: Vec<RequestSpec>) -> Result<FederationRunResult> {
        for s in &mut trace {
            if !s.arrival.is_finite() {
                s.arrival = 0.0;
            }
        }
        trace.sort_by(|a, b| a.arrival.total_cmp(&b.arrival));
        for spec in trace {
            self.submit(spec)?;
        }
        self.finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{Cluster, RoutingPolicy};
    use crate::config::SchedulerConfig;
    use crate::coordinator::engine::EngineConfig;
    use crate::gateway::Gateway;
    use crate::model::gpu::a100_4x;
    use crate::model::latency::LatencyModel;
    use crate::model::llm::opt_66b;
    use crate::workload::{ArrivalProcess, Dataset, QoeTrace, Workload};

    fn cluster(replicas: usize, kv_tokens: usize) -> Cluster {
        let latency = LatencyModel::for_deployment(&opt_66b(), &a100_4x());
        let cfg = EngineConfig {
            kv_capacity_tokens: kv_tokens,
            swap_capacity_tokens: kv_tokens * 2,
            ..EngineConfig::default()
        };
        Cluster::new(
            replicas,
            cfg,
            latency,
            &SchedulerConfig::Fcfs,
            RoutingPolicy::LeastLoaded,
        )
    }

    fn trace(n: usize, rate: f64, seed: u64) -> Vec<RequestSpec> {
        Workload {
            dataset: Dataset::ShareGpt,
            arrivals: ArrivalProcess::Poisson { rate },
            qoe_trace: QoeTrace::TextReading,
            num_requests: n,
            seed,
        }
        .generate()
    }

    fn base_cfg() -> GatewayConfig {
        let mut cfg = GatewayConfig::default();
        cfg.pacing_enabled = false;
        cfg
    }

    #[test]
    fn merge_snapshot_applies_local_ledger() {
        let snap = vec![
            ReplicaState {
                active_requests: 2,
                kv_free_tokens: 10_000,
                kv_capacity_tokens: 20_000,
                est_request_tds: 6.0,
            },
            ReplicaState {
                active_requests: 1,
                kv_free_tokens: 4_000,
                kv_capacity_tokens: 20_000,
                est_request_tds: 8.0,
            },
        ];
        let view = merge_snapshot(&snap, &[1_000, 1_000]);
        // Both admits land on replica 0 (most free KV both times).
        assert_eq!(view[0].kv_free_tokens, 8_000);
        assert_eq!(view[0].active_requests, 4);
        // Fair share re-split twice: 6.0 × 3/4 × 4/5.
        assert!((view[0].est_request_tds - 6.0 * 0.75 * 0.8).abs() < 1e-9);
        assert_eq!(view[1].kv_free_tokens, 4_000);
        // Empty ledger is the identity.
        let id = merge_snapshot(&snap, &[]);
        assert_eq!(id[0].kv_free_tokens, snap[0].kv_free_tokens);
        assert_eq!(id[1].active_requests, snap[1].active_requests);
    }

    #[test]
    fn federation_conserves_requests() {
        let reqs = trace(120, 12.0, 7);
        let mut cfg = base_cfg();
        cfg.surge.baseline_rate = 1.5;
        let fed = FederationConfig { gateways: 3, ..FederationConfig::default() };
        let mut gw = FederatedGateway::new(cluster(2, 2500), cfg, fed);
        let res = gw.run_trace(reqs).unwrap();
        assert_eq!(res.served.len() + res.rejections.len(), 120, "conservation");
        assert_eq!(res.stats.admitted + res.stats.rejected, res.stats.arrivals);
        assert!(res.stats.rejected > 0, "8x overload must shed somewhere");
        assert!(res.replica_seconds > 0.0);
    }

    #[test]
    fn single_node_federation_matches_gateway() {
        // gateways = 1 must reproduce the plain Gateway's decisions: one
        // admission controller, always-fresh state, same defer sweep.
        let reqs = trace(80, 6.0, 11);
        let mut cfg = base_cfg();
        cfg.surge.baseline_rate = 2.0;

        let mut plain = Gateway::new(cluster(2, 4000), cfg.clone());
        let pres = plain.run_trace(reqs.clone()).unwrap();

        let fed = FederationConfig::default();
        let mut fgw = FederatedGateway::new(cluster(2, 4000), cfg, fed);
        let fres = fgw.run_trace(reqs).unwrap();

        assert_eq!(fres.served.len(), pres.served.len());
        assert_eq!(fres.rejections.len(), pres.rejections.len());
        assert!(
            (fres.mean_served_qoe() - pres.mean_served_qoe()).abs() < 1e-9,
            "single-node federation {:.6} vs gateway {:.6}",
            fres.mean_served_qoe(),
            pres.mean_served_qoe()
        );
    }

    #[test]
    fn stale_sync_disagrees_more_than_fresh() {
        let reqs = trace(150, 10.0, 13);
        let mut cfg = base_cfg();
        cfg.surge.baseline_rate = 2.0;

        let run = |sync: f64, stale: f64| {
            let fed = FederationConfig {
                gateways: 4,
                sync_interval_secs: sync,
                staleness_bound_secs: stale,
            };
            let mut gw = FederatedGateway::new(cluster(2, 2500), cfg.clone(), fed);
            gw.run_trace(reqs.clone()).unwrap()
        };
        let fresh = run(0.05, 0.5);
        let stale = run(8.0, 60.0);
        assert!(fresh.stats.syncs > stale.stats.syncs);
        // Stale views miss peers' admissions, so nodes believe in
        // headroom that is long gone and over-admit relative to fresh
        // sync (the TokenFlow stale-state failure mode).
        assert!(
            stale.stats.admitted >= fresh.stats.admitted,
            "stale sync admitted {} < fresh {}",
            stale.stats.admitted,
            fresh.stats.admitted
        );
        assert!(
            stale.stats.disagreements > 0,
            "4 nodes on 8s-old snapshots at 8x overload must disagree somewhere"
        );
        // Both probed every arrival, and rates are well-formed.
        assert_eq!(fresh.stats.probed, 150);
        assert_eq!(stale.stats.probed, 150);
        assert!((0.0..=1.0).contains(&fresh.stats.disagreement_rate()));
        assert!((0.0..=1.0).contains(&stale.stats.disagreement_rate()));
    }

    #[test]
    fn staleness_bound_forces_refreshes() {
        // Long sync interval + tight staleness bound: nodes must refresh
        // individually instead of acting on ancient snapshots.
        let reqs = trace(60, 2.0, 17);
        let cfg = base_cfg();
        let fed = FederationConfig {
            gateways: 2,
            sync_interval_secs: 1_000.0,
            staleness_bound_secs: 1.0,
        };
        let mut gw = FederatedGateway::new(cluster(1, 100_000), cfg, fed);
        let res = gw.run_trace(reqs).unwrap();
        assert!(
            res.stats.forced_refreshes > 0,
            "a 30s trace with a 1s bound must force refreshes"
        );
        assert_eq!(res.served.len(), 60, "light load serves everything");
    }

    #[test]
    fn tier_weighted_federation_protects_premium() {
        // Tiered workload at heavy overload: premium weight 2 must not
        // serve a smaller fraction of premium arrivals than tier-blind.
        let wl = Workload {
            dataset: Dataset::ShareGpt,
            arrivals: ArrivalProcess::Poisson { rate: 12.0 },
            qoe_trace: QoeTrace::Tiered,
            num_requests: 150,
            seed: 23,
        };
        let reqs = wl.generate();
        let premium_ids: Vec<usize> = reqs
            .iter()
            .filter(|r| QoeTrace::tier_of(&r.qoe) == "premium")
            .map(|r| r.id)
            .collect();
        assert!(!premium_ids.is_empty());

        let run = |weights: crate::gateway::TierWeights| {
            let mut cfg = base_cfg();
            cfg.surge.baseline_rate = 1.5;
            cfg.admission.tier_weights = weights;
            let fed = FederationConfig { gateways: 2, ..FederationConfig::default() };
            let mut gw = FederatedGateway::new(cluster(2, 2500), cfg, fed);
            let res = gw.run_trace(reqs.clone()).unwrap();
            let rejected_premium = res
                .rejections
                .iter()
                .filter(|r| premium_ids.contains(&r.id))
                .count();
            (res, rejected_premium)
        };
        let (blind, blind_rejects) = run(crate::gateway::TierWeights::default());
        let (weighted, weighted_rejects) = run(crate::gateway::TierWeights {
            premium: 2.0,
            standard: 1.0,
            economy: 0.5,
        });
        assert_eq!(
            blind.served.len() + blind.rejections.len(),
            weighted.served.len() + weighted.rejections.len(),
            "both runs conserve"
        );
        assert!(
            weighted_rejects <= blind_rejects,
            "premium weight 2 rejected more premium ({weighted_rejects}) than \
             tier-blind ({blind_rejects})"
        );
    }
}
