//! QoE-aware admission control.
//!
//! Every arriving request is scored against the current serving state:
//!
//! - **expected QoE gain** — the per-request token delivery speed the
//!   serving tier could give one more request (fair share of the
//!   KV-bounded batch throughput), relative to the request's expected
//!   TDS. A request predicted to stream far below its digestion speed
//!   contributes almost no QoE but still consumes capacity;
//! - **marginal resource cost** — the fraction of the best replica's
//!   free KV the request's context (prompt + expected output) would
//!   claim;
//! - **tier weight** — an optional per-tier multiplier
//!   ([`TierWeights`], paper §6.1's price tiers) on the expected-QoE
//!   score, so premium traffic survives shedding that economy traffic
//!   absorbs. Uniform weights (the default) reproduce tier-blind
//!   admission exactly.
//!
//! Normal mode never sheds: requests that don't currently fit are
//! deferred to a bounded queue, re-examined at its own deadlines (not
//! just at the next arrival) with one final admission check at expiry.
//! Surge mode (see [`super::surge`]) escalates to structured rejection,
//! so clients get an immediate, actionable answer instead of a token
//! stream that arrives too late to matter (the TokenFlow/DiSCo argument
//! for front-end preemptive decisions). A hysteresis latch keeps
//! decisions from flapping when the predicted QoE hovers at the
//! admission floor.
//!
//! ```
//! use andes::gateway::{AdmissionConfig, AdmissionController, AdmissionDecision,
//!                      LoadMode, ReplicaState};
//! use andes::qoe::spec::QoeSpec;
//!
//! let mut ctl = AdmissionController::new(AdmissionConfig::default());
//! let healthy = [ReplicaState {
//!     active_requests: 4,
//!     kv_free_tokens: 50_000,
//!     kv_capacity_tokens: 70_000,
//!     est_request_tds: 12.0,
//! }];
//! let spec = QoeSpec::new(1.0, 4.8);
//! assert_eq!(
//!     ctl.decide(200, &spec, &healthy, LoadMode::Normal, 0),
//!     AdmissionDecision::Admit
//! );
//! ```

use anyhow::{bail, Result};

use crate::qoe::spec::QoeSpec;
use crate::workload::qoe_trace::QoeTrace;

use super::surge::LoadMode;

/// How much of the gap to perfect predicted QoE a *fully* parked prompt
/// closes (the prefix-hit TTFT relief of
/// [`AdmissionController::decide_with_prefix`]); partial prefixes scale
/// linearly.
const PREFIX_TTFT_RELIEF: f64 = 0.5;

/// Snapshot of one serving replica, as the gateway sees it.
#[derive(Debug, Clone, Copy)]
pub struct ReplicaState {
    /// Active (unfinished) requests: running + waiting + swapped.
    pub active_requests: usize,
    /// Free device KV tokens.
    pub kv_free_tokens: usize,
    /// Total device KV tokens.
    pub kv_capacity_tokens: usize,
    /// Estimated per-request token delivery speed (tok/s) if one more
    /// request were admitted: the fair share of the KV-bounded batch
    /// throughput across `active_requests + 1` requests.
    pub est_request_tds: f64,
}

impl ReplicaState {
    /// Fraction of device KV in use ∈ [0, 1].
    pub fn kv_utilization(&self) -> f64 {
        if self.kv_capacity_tokens == 0 {
            return 1.0;
        }
        1.0 - self.kv_free_tokens as f64 / self.kv_capacity_tokens as f64
    }
}

/// Per-tier admission weights (paper §6.1's API price tiers). Each
/// weight multiplies the tier's predicted-QoE score before the
/// admission floor is applied and orders the gateway's defer queue, so
/// a tier with weight 2 is shed half as eagerly as one with weight 1
/// and jumps ahead of it while deferred. All-ones (the default) is
/// tier-blind: decisions are bit-identical to the unweighted path.
///
/// ```
/// use andes::gateway::TierWeights;
/// use andes::qoe::spec::QoeSpec;
///
/// let w = TierWeights::parse("2:1:0.5").unwrap();
/// assert_eq!(w.weight_for(&QoeSpec::new(0.5, 6.5)), 2.0); // premium
/// assert_eq!(w.weight_for(&QoeSpec::new(2.0, 2.5)), 0.5); // economy
/// assert!(TierWeights::default().is_uniform());
/// ```
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TierWeights {
    pub premium: f64,
    pub standard: f64,
    pub economy: f64,
}

impl Default for TierWeights {
    fn default() -> Self {
        TierWeights { premium: 1.0, standard: 1.0, economy: 1.0 }
    }
}

impl TierWeights {
    /// Parse the CLI/`"tiers"` form `premium:standard:economy`,
    /// e.g. `2:1:0.5`. All weights must be positive.
    pub fn parse(s: &str) -> Result<TierWeights> {
        let parts: Vec<&str> = s.split(':').collect();
        if parts.len() != 3 {
            bail!("tier weights must be premium:standard:economy, got '{s}'");
        }
        let mut vals = [0.0f64; 3];
        for (v, p) in vals.iter_mut().zip(&parts) {
            *v = p
                .trim()
                .parse::<f64>()
                .map_err(|_| anyhow::anyhow!("bad tier weight '{p}' in '{s}'"))?;
            if !v.is_finite() || *v <= 0.0 {
                bail!("tier weights must be positive and finite, got '{p}'");
            }
        }
        Ok(TierWeights { premium: vals[0], standard: vals[1], economy: vals[2] })
    }

    /// Whether every tier carries the same weight (decisions reduce to
    /// the tier-blind path).
    pub fn is_uniform(&self) -> bool {
        self.premium == self.standard && self.standard == self.economy
    }

    /// Weight of the tier a sampled QoE spec belongs to (tier membership
    /// follows [`QoeTrace::tier_of`]; non-tiered traces map to
    /// "standard").
    pub fn weight_for(&self, spec: &QoeSpec) -> f64 {
        match QoeTrace::tier_of(spec) {
            "premium" => self.premium,
            "economy" => self.economy,
            _ => self.standard,
        }
    }
}

/// Admission controller configuration.
#[derive(Debug, Clone)]
pub struct AdmissionConfig {
    /// Expected output length for the marginal KV cost estimate (tokens).
    pub expected_output_tokens: usize,
    /// Admission floor: predicted per-request QoE below this sheds load.
    pub min_predicted_qoe: f64,
    /// Hysteresis band above the floor before shedding stops: once
    /// shedding starts, it only stops when the predicted QoE recovers
    /// past `min_predicted_qoe + hysteresis`.
    pub hysteresis: f64,
    /// Max requests in the defer queue before rejecting outright.
    pub max_deferred: usize,
    /// Longest a deferred request may wait in the defer queue (s). The
    /// gateway sweeps the queue at this deadline (not at the next
    /// arrival) and gives the request one final admission check before
    /// expiring it.
    pub max_defer_wait: f64,
    /// Per-tier multipliers on the predicted-QoE score and defer-queue
    /// priority (uniform = tier-blind).
    pub tier_weights: TierWeights,
}

impl Default for AdmissionConfig {
    fn default() -> Self {
        AdmissionConfig {
            expected_output_tokens: 260, // ShareGPT mean response length
            min_predicted_qoe: 0.35,
            hysteresis: 0.1,
            max_deferred: 64,
            max_defer_wait: 10.0,
            tier_weights: TierWeights::default(),
        }
    }
}

/// Structured rejection reasons, surfaced to clients verbatim.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum RejectReason {
    /// No replica has KV headroom for the request's expected context.
    Saturated { kv_utilization: f64 },
    /// Surge shedding: predicted QoE below the admission floor.
    SurgeShed { predicted_qoe: f64 },
    /// The defer queue is full.
    QueueFull { depth: usize },
    /// Deferred past the maximum wait without capacity freeing up.
    DeferTimeout { waited: f64 },
}

impl RejectReason {
    pub fn label(&self) -> &'static str {
        match self {
            RejectReason::Saturated { .. } => "saturated",
            RejectReason::SurgeShed { .. } => "surge-shed",
            RejectReason::QueueFull { .. } => "queue-full",
            RejectReason::DeferTimeout { .. } => "defer-timeout",
        }
    }

    pub fn detail(&self) -> String {
        match self {
            RejectReason::Saturated { kv_utilization } => {
                format!("kv utilization {kv_utilization:.2}")
            }
            RejectReason::SurgeShed { predicted_qoe } => {
                format!("predicted QoE {predicted_qoe:.2} below admission floor")
            }
            RejectReason::QueueFull { depth } => {
                format!("admission queue depth {depth}")
            }
            RejectReason::DeferTimeout { waited } => {
                format!("deferred {waited:.1}s without capacity")
            }
        }
    }
}

/// Per-request admission verdict.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum AdmissionDecision {
    Admit,
    /// Park in the gateway's weight-ordered queue. The gateway
    /// re-examines the queue by *event-stepping* — as capacity frees
    /// and at each request's own deadline — not merely when the next
    /// request happens to arrive.
    Defer,
    Reject(RejectReason),
}

/// The admission controller: stateless scoring plus a hysteresis latch.
#[derive(Debug, Clone)]
pub struct AdmissionController {
    cfg: AdmissionConfig,
    /// Latched shedding state (see `AdmissionConfig::hysteresis`).
    shedding: bool,
}

impl AdmissionController {
    pub fn new(cfg: AdmissionConfig) -> Self {
        assert!(
            (0.0..=1.0).contains(&cfg.min_predicted_qoe),
            "admission floor must be in [0, 1]"
        );
        assert!(cfg.hysteresis >= 0.0, "hysteresis must be non-negative");
        AdmissionController { cfg, shedding: false }
    }

    pub fn config(&self) -> &AdmissionConfig {
        &self.cfg
    }

    /// Whether the controller is currently shedding (diagnostics).
    pub fn is_shedding(&self) -> bool {
        self.shedding
    }

    /// Predicted QoE for a new request on `replica`: achievable delivery
    /// speed relative to the expected TDS (TTFT effects excluded — the
    /// dominant term under load is sustained speed).
    pub fn predicted_qoe(&self, replica: &ReplicaState, spec: &QoeSpec) -> f64 {
        (replica.est_request_tds / spec.tds).clamp(0.0, 1.0)
    }

    /// Marginal KV cost on `replica`: expected context over free tokens.
    /// Values above 1 mean the request cannot currently fit there.
    pub fn marginal_cost(&self, replica: &ReplicaState, prompt_tokens: usize) -> f64 {
        let need = (prompt_tokens + self.cfg.expected_output_tokens) as f64;
        need / replica.kv_free_tokens.max(1) as f64
    }

    /// Decide the fate of a request with `prompt_tokens` and QoE spec
    /// `qoe`, given the replica snapshots, the load mode, and the current
    /// defer-queue depth.
    ///
    /// The hysteresis latch is driven by the *unweighted* predicted QoE
    /// (it tracks system state, not any one tier); the per-request shed
    /// test then compares the tier-weighted score against the latched
    /// floor. Raising a tier's weight therefore only ever moves that
    /// tier's decisions toward admission (the monotonicity property
    /// tested in `tests/integration.rs`), and uniform weights reproduce
    /// the tier-blind decisions exactly.
    pub fn decide(
        &mut self,
        prompt_tokens: usize,
        qoe: &QoeSpec,
        replicas: &[ReplicaState],
        mode: LoadMode,
        queue_depth: usize,
    ) -> AdmissionDecision {
        self.decide_with_prefix(prompt_tokens, 0, qoe, replicas, mode, queue_depth)
    }

    /// [`Self::decide`] for a request whose leading `prefix_tokens` are
    /// parked on the serving tier (a returning session turn, DESIGN.md
    /// §10): the prefix skips prefill, shortening expected TTFT, which
    /// feeds into the predicted-QoE score as a relief proportional to
    /// the skipped fraction of the prompt. The relief applies to the
    /// per-request score only — the hysteresis latch stays driven by
    /// the unweighted, prefix-blind score (it tracks system state, not
    /// one request's cache luck) — so `prefix_tokens == 0` reproduces
    /// [`Self::decide`] bit-identically and a larger prefix only ever
    /// moves the decision toward admission.
    pub fn decide_with_prefix(
        &mut self,
        prompt_tokens: usize,
        prefix_tokens: usize,
        qoe: &QoeSpec,
        replicas: &[ReplicaState],
        mode: LoadMode,
        queue_depth: usize,
    ) -> AdmissionDecision {
        if replicas.is_empty() {
            return AdmissionDecision::Reject(RejectReason::Saturated { kv_utilization: 1.0 });
        }
        let best_pred = replicas
            .iter()
            .map(|r| self.predicted_qoe(r, qoe))
            .fold(0.0f64, f64::max);
        let fits = replicas.iter().any(|r| self.marginal_cost(r, prompt_tokens) <= 1.0);
        let min_util = replicas
            .iter()
            .map(|r| r.kv_utilization())
            .fold(f64::INFINITY, f64::min);

        // Hysteresis latch on the (unweighted) predicted-QoE floor.
        if self.shedding {
            if best_pred >= (self.cfg.min_predicted_qoe + self.cfg.hysteresis).min(1.0) {
                self.shedding = false;
            }
        } else if best_pred < self.cfg.min_predicted_qoe {
            self.shedding = true;
        }

        // Prefix-hit TTFT relief: the parked fraction of the prompt
        // skips prefill compute, closing part of the gap between the
        // predicted and the perfect QoE (first-order model; the
        // sustained-speed term is untouched).
        let prefix_frac =
            prefix_tokens.min(prompt_tokens) as f64 / prompt_tokens.max(1) as f64;
        let relieved_pred =
            (best_pred + (1.0 - best_pred) * PREFIX_TTFT_RELIEF * prefix_frac).clamp(0.0, 1.0);

        // Per-request shed test: tier-weighted score vs. the latched
        // floor. While the latch is on, the floor includes the
        // hysteresis band — with weight 1 that is exactly "latched ⇒
        // shed", because the latch releases at the same threshold.
        let weighted_pred =
            (relieved_pred * self.cfg.tier_weights.weight_for(qoe)).clamp(0.0, 1.0);
        let floor = if self.shedding {
            (self.cfg.min_predicted_qoe + self.cfg.hysteresis).min(1.0)
        } else {
            self.cfg.min_predicted_qoe
        };
        let shed_this = weighted_pred < floor;

        match mode {
            LoadMode::Surge => {
                if shed_this {
                    // Report the *actual* predicted QoE, not the
                    // weighted score — the client-visible reject detail
                    // must not fabricate a QoE number.
                    AdmissionDecision::Reject(RejectReason::SurgeShed {
                        predicted_qoe: best_pred,
                    })
                } else if !fits {
                    AdmissionDecision::Reject(RejectReason::Saturated {
                        kv_utilization: min_util,
                    })
                } else {
                    AdmissionDecision::Admit
                }
            }
            LoadMode::Normal => {
                if shed_this || !fits {
                    if queue_depth >= self.cfg.max_deferred {
                        AdmissionDecision::Reject(RejectReason::QueueFull {
                            depth: queue_depth,
                        })
                    } else {
                        AdmissionDecision::Defer
                    }
                } else {
                    AdmissionDecision::Admit
                }
            }
        }
    }

    /// The decision [`Self::decide`] would return right now, without
    /// mutating the hysteresis latch — the federation layer's
    /// disagreement probe asks every peer this question on each arrival.
    pub fn preview(
        &self,
        prompt_tokens: usize,
        qoe: &QoeSpec,
        replicas: &[ReplicaState],
        mode: LoadMode,
        queue_depth: usize,
    ) -> AdmissionDecision {
        let mut scratch = self.clone();
        scratch.decide(prompt_tokens, qoe, replicas, mode, queue_depth)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> QoeSpec {
        QoeSpec::new(1.0, 4.8)
    }

    fn replica(active: usize, free: usize, tds: f64) -> ReplicaState {
        ReplicaState {
            active_requests: active,
            kv_free_tokens: free,
            kv_capacity_tokens: 70_000,
            est_request_tds: tds,
        }
    }

    fn ctl() -> AdmissionController {
        AdmissionController::new(AdmissionConfig::default())
    }

    #[test]
    fn healthy_state_admits() {
        let mut c = ctl();
        let r = [replica(10, 50_000, 12.0)];
        assert_eq!(
            c.decide(200, &spec(), &r, LoadMode::Normal, 0),
            AdmissionDecision::Admit
        );
        assert_eq!(
            c.decide(200, &spec(), &r, LoadMode::Surge, 0),
            AdmissionDecision::Admit
        );
    }

    #[test]
    fn surge_sheds_below_floor_normal_defers() {
        let mut c = ctl();
        // Predicted share 1.0 tok/s ≪ 4.8 expected → predicted QoE ≈ 0.21.
        let r = [replica(400, 5_000, 1.0)];
        match c.decide(200, &spec(), &r, LoadMode::Surge, 0) {
            AdmissionDecision::Reject(RejectReason::SurgeShed { predicted_qoe }) => {
                assert!(predicted_qoe < 0.35, "{predicted_qoe}");
            }
            other => panic!("expected surge shed, got {other:?}"),
        }
        let mut c = ctl();
        assert_eq!(
            c.decide(200, &spec(), &r, LoadMode::Normal, 0),
            AdmissionDecision::Defer
        );
    }

    #[test]
    fn queue_full_rejects_in_normal_mode() {
        let mut c = ctl();
        let r = [replica(400, 5_000, 1.0)];
        let depth = c.config().max_deferred;
        assert_eq!(
            c.decide(200, &spec(), &r, LoadMode::Normal, depth),
            AdmissionDecision::Reject(RejectReason::QueueFull { depth })
        );
    }

    #[test]
    fn oversized_request_defers_then_admits_when_fitting() {
        let mut c = ctl();
        // Plenty of speed but no KV headroom for a 900-token prompt.
        let tight = [replica(3, 500, 12.0)];
        assert_eq!(
            c.decide(900, &spec(), &tight, LoadMode::Normal, 0),
            AdmissionDecision::Defer
        );
        let roomy = [replica(3, 5_000, 12.0)];
        assert_eq!(
            c.decide(900, &spec(), &roomy, LoadMode::Normal, 0),
            AdmissionDecision::Admit
        );
    }

    #[test]
    fn best_replica_wins() {
        // One saturated replica must not condemn the request when a
        // healthy one exists.
        let mut c = ctl();
        let r = [replica(500, 100, 0.5), replica(5, 60_000, 10.0)];
        assert_eq!(
            c.decide(300, &spec(), &r, LoadMode::Surge, 0),
            AdmissionDecision::Admit
        );
    }

    #[test]
    fn hysteresis_prevents_decision_flapping() {
        // Floor 0.35, hysteresis 0.1 → shed below 1.68 tok/s, recover
        // above 2.16 tok/s (for tds 4.8). A share oscillating inside the
        // band must not flip decisions.
        let mut c = ctl();
        let sp = spec();
        let shed = |tds: f64| [replica(300, 30_000, tds)];
        // Trip the latch.
        assert!(matches!(
            c.decide(200, &sp, &shed(1.6), LoadMode::Surge, 0),
            AdmissionDecision::Reject(_)
        ));
        // Oscillate inside the band: still shedding, every time.
        for _ in 0..10 {
            for tds in [1.75, 1.6, 2.0, 1.7] {
                assert!(
                    matches!(
                        c.decide(200, &sp, &shed(tds), LoadMode::Surge, 0),
                        AdmissionDecision::Reject(RejectReason::SurgeShed { .. })
                    ),
                    "flapped at share {tds}"
                );
            }
        }
        // Clear recovery past floor + hysteresis → admit again.
        assert_eq!(
            c.decide(200, &sp, &shed(2.3), LoadMode::Surge, 0),
            AdmissionDecision::Admit
        );
    }

    #[test]
    fn marginal_cost_and_predicted_qoe_scales() {
        let c = ctl();
        let r = replica(10, 1_000, 2.4);
        assert!((c.predicted_qoe(&r, &spec()) - 0.5).abs() < 1e-9);
        // 200 prompt + 260 expected output over 1000 free.
        assert!((c.marginal_cost(&r, 200) - 0.46).abs() < 1e-9);
        assert!((r.kv_utilization() - (1.0 - 1_000.0 / 70_000.0)).abs() < 1e-12);
    }

    #[test]
    fn no_replicas_rejects() {
        let mut c = ctl();
        assert!(matches!(
            c.decide(100, &spec(), &[], LoadMode::Normal, 0),
            AdmissionDecision::Reject(RejectReason::Saturated { .. })
        ));
    }

    #[test]
    fn tier_weights_parse_and_classify() {
        let w = TierWeights::parse("2:1:0.5").unwrap();
        assert_eq!(w, TierWeights { premium: 2.0, standard: 1.0, economy: 0.5 });
        assert!(!w.is_uniform());
        assert!(TierWeights::default().is_uniform());
        // Tier membership mirrors QoeTrace::tier_of.
        assert_eq!(w.weight_for(&QoeSpec::new(0.5, 6.5)), 2.0);
        assert_eq!(w.weight_for(&QoeSpec::new(1.0, 4.8)), 1.0);
        assert_eq!(w.weight_for(&QoeSpec::new(2.0, 2.5)), 0.5);
        for bad in ["", "2:1", "1:2:3:4", "a:1:1", "0:1:1", "-1:1:1", "inf:1:1"] {
            assert!(TierWeights::parse(bad).is_err(), "{bad}");
        }
    }

    #[test]
    fn uniform_weights_reproduce_tier_blind_decisions() {
        // Any uniform weight vector must give exactly the default-config
        // decisions across a load ramp (the latch histories coincide).
        let mut blind = ctl();
        let mut uniform = AdmissionController::new(AdmissionConfig {
            tier_weights: TierWeights { premium: 1.0, standard: 1.0, economy: 1.0 },
            ..AdmissionConfig::default()
        });
        let sp = spec();
        for tds in [12.0, 3.0, 1.2, 0.6, 1.9, 2.3, 6.0, 12.0] {
            let r = [replica(100, 20_000, tds)];
            for mode in [LoadMode::Normal, LoadMode::Surge] {
                assert_eq!(
                    blind.decide(300, &sp, &r, mode, 2),
                    uniform.decide(300, &sp, &r, mode, 2),
                    "diverged at tds {tds} mode {mode:?}"
                );
            }
        }
    }

    #[test]
    fn premium_weight_survives_shedding_economy_sheds_earlier() {
        let weights = TierWeights { premium: 2.0, standard: 1.0, economy: 0.5 };
        let mut c = AdmissionController::new(AdmissionConfig {
            tier_weights: weights,
            ..AdmissionConfig::default()
        });
        // Unweighted predicted QoE for premium (tds 6.5) with a 1.6 tok/s
        // share is ~0.25 (< 0.35 floor); weighted ×2 → ~0.49 admits.
        let r = [replica(200, 30_000, 1.6)];
        let premium = QoeSpec::new(0.5, 6.5);
        assert_eq!(
            c.decide(200, &premium, &r, LoadMode::Surge, 0),
            AdmissionDecision::Admit,
            "premium must ride out the shed band"
        );
        // Economy (tds 2.5) at the same share predicts 0.64 unweighted —
        // comfortably above the floor — but ×0.5 → 0.32 sheds.
        let economy = QoeSpec::new(2.0, 2.5);
        assert!(matches!(
            c.decide(200, &economy, &r, LoadMode::Surge, 0),
            AdmissionDecision::Reject(RejectReason::SurgeShed { .. })
        ));
    }

    #[test]
    fn prefix_relief_rescues_marginal_requests_only() {
        // Share 1.2 tok/s vs expected 4.8 → predicted 0.25, below the
        // 0.35 floor: a cold request sheds under surge. A parked prefix
        // covering most of the prompt skips its prefill and relieves
        // the score past the floor.
        let r = [replica(200, 30_000, 1.2)];
        let sp = spec();
        let mut c = ctl();
        assert!(matches!(
            c.decide_with_prefix(800, 0, &sp, &r, LoadMode::Surge, 0),
            AdmissionDecision::Reject(RejectReason::SurgeShed { .. })
        ));
        let mut c = ctl();
        assert_eq!(
            c.decide_with_prefix(800, 800, &sp, &r, LoadMode::Surge, 0),
            AdmissionDecision::Admit,
            "a fully parked prompt must ride out the marginal shed"
        );
        // A negligible prefix gives negligible relief: still shed.
        let mut c = ctl();
        assert!(matches!(
            c.decide_with_prefix(800, 8, &sp, &r, LoadMode::Surge, 0),
            AdmissionDecision::Reject(RejectReason::SurgeShed { .. }),
            "a 1% prefix must not rescue a shed request"
        ));
    }

    #[test]
    fn prefix_relief_is_monotone() {
        // A larger parked prefix never demotes an admit.
        let r = [replica(200, 30_000, 1.2)];
        let sp = spec();
        let mut last_admitted = false;
        for prefix in [0usize, 100, 200, 400, 600, 800] {
            let mut c = ctl();
            let admitted = c.decide_with_prefix(800, prefix, &sp, &r, LoadMode::Surge, 0)
                == AdmissionDecision::Admit;
            assert!(
                admitted || !last_admitted,
                "prefix {prefix} demoted an admit"
            );
            last_admitted = admitted;
        }
    }

    #[test]
    fn preview_matches_decide_without_latch_mutation() {
        let mut c = ctl();
        let sp = spec();
        let low = [replica(400, 30_000, 1.0)];
        let high = [replica(4, 60_000, 12.0)];
        // Preview must predict what decide returns…
        let p = c.preview(200, &sp, &low, LoadMode::Surge, 0);
        assert_eq!(p, c.decide(200, &sp, &low, LoadMode::Surge, 0));
        assert!(c.is_shedding());
        // …and previewing a recovered state must not release the latch.
        let _ = c.preview(200, &sp, &high, LoadMode::Surge, 0);
        assert!(c.is_shedding(), "preview must not mutate the latch");
    }
}
