//! QoE-aware admission control.
//!
//! Every arriving request is scored against the current serving state:
//!
//! - **expected QoE gain** — the per-request token delivery speed the
//!   serving tier could give one more request (fair share of the
//!   KV-bounded batch throughput), relative to the request's expected
//!   TDS. A request predicted to stream far below its digestion speed
//!   contributes almost no QoE but still consumes capacity;
//! - **marginal resource cost** — the fraction of the best replica's
//!   free KV the request's context (prompt + expected output) would
//!   claim.
//!
//! Normal mode never sheds: requests that don't currently fit are
//! deferred to a bounded queue. Surge mode (see [`super::surge`])
//! escalates to structured rejection, so clients get an immediate,
//! actionable answer instead of a token stream that arrives too late to
//! matter (the TokenFlow/DiSCo argument for front-end preemptive
//! decisions). A hysteresis latch keeps decisions from flapping when
//! the predicted QoE hovers at the admission floor.

use crate::qoe::spec::QoeSpec;

use super::surge::LoadMode;

/// Snapshot of one serving replica, as the gateway sees it.
#[derive(Debug, Clone, Copy)]
pub struct ReplicaState {
    /// Active (unfinished) requests: running + waiting + swapped.
    pub active_requests: usize,
    /// Free device KV tokens.
    pub kv_free_tokens: usize,
    /// Total device KV tokens.
    pub kv_capacity_tokens: usize,
    /// Estimated per-request token delivery speed (tok/s) if one more
    /// request were admitted: the fair share of the KV-bounded batch
    /// throughput across `active_requests + 1` requests.
    pub est_request_tds: f64,
}

impl ReplicaState {
    /// Fraction of device KV in use ∈ [0, 1].
    pub fn kv_utilization(&self) -> f64 {
        if self.kv_capacity_tokens == 0 {
            return 1.0;
        }
        1.0 - self.kv_free_tokens as f64 / self.kv_capacity_tokens as f64
    }
}

/// Admission controller configuration.
#[derive(Debug, Clone)]
pub struct AdmissionConfig {
    /// Expected output length for the marginal KV cost estimate (tokens).
    pub expected_output_tokens: usize,
    /// Admission floor: predicted per-request QoE below this sheds load.
    pub min_predicted_qoe: f64,
    /// Hysteresis band above the floor before shedding stops: once
    /// shedding starts, it only stops when the predicted QoE recovers
    /// past `min_predicted_qoe + hysteresis`.
    pub hysteresis: f64,
    /// Max requests in the defer queue before rejecting outright.
    pub max_deferred: usize,
    /// Longest a deferred request may wait before rejection (s).
    pub max_defer_wait: f64,
}

impl Default for AdmissionConfig {
    fn default() -> Self {
        AdmissionConfig {
            expected_output_tokens: 260, // ShareGPT mean response length
            min_predicted_qoe: 0.35,
            hysteresis: 0.1,
            max_deferred: 64,
            max_defer_wait: 10.0,
        }
    }
}

/// Structured rejection reasons, surfaced to clients verbatim.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum RejectReason {
    /// No replica has KV headroom for the request's expected context.
    Saturated { kv_utilization: f64 },
    /// Surge shedding: predicted QoE below the admission floor.
    SurgeShed { predicted_qoe: f64 },
    /// The defer queue is full.
    QueueFull { depth: usize },
    /// Deferred past the maximum wait without capacity freeing up.
    DeferTimeout { waited: f64 },
}

impl RejectReason {
    pub fn label(&self) -> &'static str {
        match self {
            RejectReason::Saturated { .. } => "saturated",
            RejectReason::SurgeShed { .. } => "surge-shed",
            RejectReason::QueueFull { .. } => "queue-full",
            RejectReason::DeferTimeout { .. } => "defer-timeout",
        }
    }

    pub fn detail(&self) -> String {
        match self {
            RejectReason::Saturated { kv_utilization } => {
                format!("kv utilization {kv_utilization:.2}")
            }
            RejectReason::SurgeShed { predicted_qoe } => {
                format!("predicted QoE {predicted_qoe:.2} below admission floor")
            }
            RejectReason::QueueFull { depth } => {
                format!("admission queue depth {depth}")
            }
            RejectReason::DeferTimeout { waited } => {
                format!("deferred {waited:.1}s without capacity")
            }
        }
    }
}

/// Per-request admission verdict.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum AdmissionDecision {
    Admit,
    /// Park in the gateway queue until capacity frees (bounded wait).
    Defer,
    Reject(RejectReason),
}

/// The admission controller: stateless scoring plus a hysteresis latch.
#[derive(Debug, Clone)]
pub struct AdmissionController {
    cfg: AdmissionConfig,
    /// Latched shedding state (see `AdmissionConfig::hysteresis`).
    shedding: bool,
}

impl AdmissionController {
    pub fn new(cfg: AdmissionConfig) -> Self {
        assert!(
            (0.0..=1.0).contains(&cfg.min_predicted_qoe),
            "admission floor must be in [0, 1]"
        );
        assert!(cfg.hysteresis >= 0.0, "hysteresis must be non-negative");
        AdmissionController { cfg, shedding: false }
    }

    pub fn config(&self) -> &AdmissionConfig {
        &self.cfg
    }

    /// Whether the controller is currently shedding (diagnostics).
    pub fn is_shedding(&self) -> bool {
        self.shedding
    }

    /// Predicted QoE for a new request on `replica`: achievable delivery
    /// speed relative to the expected TDS (TTFT effects excluded — the
    /// dominant term under load is sustained speed).
    pub fn predicted_qoe(&self, replica: &ReplicaState, spec: &QoeSpec) -> f64 {
        (replica.est_request_tds / spec.tds).clamp(0.0, 1.0)
    }

    /// Marginal KV cost on `replica`: expected context over free tokens.
    /// Values above 1 mean the request cannot currently fit there.
    pub fn marginal_cost(&self, replica: &ReplicaState, prompt_tokens: usize) -> f64 {
        let need = (prompt_tokens + self.cfg.expected_output_tokens) as f64;
        need / replica.kv_free_tokens.max(1) as f64
    }

    /// Decide the fate of a request with `prompt_tokens` and QoE spec
    /// `qoe`, given the replica snapshots, the load mode, and the current
    /// defer-queue depth.
    pub fn decide(
        &mut self,
        prompt_tokens: usize,
        qoe: &QoeSpec,
        replicas: &[ReplicaState],
        mode: LoadMode,
        queue_depth: usize,
    ) -> AdmissionDecision {
        if replicas.is_empty() {
            return AdmissionDecision::Reject(RejectReason::Saturated { kv_utilization: 1.0 });
        }
        let best_pred = replicas
            .iter()
            .map(|r| self.predicted_qoe(r, qoe))
            .fold(0.0f64, f64::max);
        let fits = replicas.iter().any(|r| self.marginal_cost(r, prompt_tokens) <= 1.0);
        let min_util = replicas
            .iter()
            .map(|r| r.kv_utilization())
            .fold(f64::INFINITY, f64::min);

        // Hysteresis latch on the predicted-QoE floor.
        if self.shedding {
            if best_pred >= (self.cfg.min_predicted_qoe + self.cfg.hysteresis).min(1.0) {
                self.shedding = false;
            }
        } else if best_pred < self.cfg.min_predicted_qoe {
            self.shedding = true;
        }

        match mode {
            LoadMode::Surge => {
                if self.shedding {
                    AdmissionDecision::Reject(RejectReason::SurgeShed {
                        predicted_qoe: best_pred,
                    })
                } else if !fits {
                    AdmissionDecision::Reject(RejectReason::Saturated {
                        kv_utilization: min_util,
                    })
                } else {
                    AdmissionDecision::Admit
                }
            }
            LoadMode::Normal => {
                if self.shedding || !fits {
                    if queue_depth >= self.cfg.max_deferred {
                        AdmissionDecision::Reject(RejectReason::QueueFull {
                            depth: queue_depth,
                        })
                    } else {
                        AdmissionDecision::Defer
                    }
                } else {
                    AdmissionDecision::Admit
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> QoeSpec {
        QoeSpec::new(1.0, 4.8)
    }

    fn replica(active: usize, free: usize, tds: f64) -> ReplicaState {
        ReplicaState {
            active_requests: active,
            kv_free_tokens: free,
            kv_capacity_tokens: 70_000,
            est_request_tds: tds,
        }
    }

    fn ctl() -> AdmissionController {
        AdmissionController::new(AdmissionConfig::default())
    }

    #[test]
    fn healthy_state_admits() {
        let mut c = ctl();
        let r = [replica(10, 50_000, 12.0)];
        assert_eq!(
            c.decide(200, &spec(), &r, LoadMode::Normal, 0),
            AdmissionDecision::Admit
        );
        assert_eq!(
            c.decide(200, &spec(), &r, LoadMode::Surge, 0),
            AdmissionDecision::Admit
        );
    }

    #[test]
    fn surge_sheds_below_floor_normal_defers() {
        let mut c = ctl();
        // Predicted share 1.0 tok/s ≪ 4.8 expected → predicted QoE ≈ 0.21.
        let r = [replica(400, 5_000, 1.0)];
        match c.decide(200, &spec(), &r, LoadMode::Surge, 0) {
            AdmissionDecision::Reject(RejectReason::SurgeShed { predicted_qoe }) => {
                assert!(predicted_qoe < 0.35, "{predicted_qoe}");
            }
            other => panic!("expected surge shed, got {other:?}"),
        }
        let mut c = ctl();
        assert_eq!(
            c.decide(200, &spec(), &r, LoadMode::Normal, 0),
            AdmissionDecision::Defer
        );
    }

    #[test]
    fn queue_full_rejects_in_normal_mode() {
        let mut c = ctl();
        let r = [replica(400, 5_000, 1.0)];
        let depth = c.config().max_deferred;
        assert_eq!(
            c.decide(200, &spec(), &r, LoadMode::Normal, depth),
            AdmissionDecision::Reject(RejectReason::QueueFull { depth })
        );
    }

    #[test]
    fn oversized_request_defers_then_admits_when_fitting() {
        let mut c = ctl();
        // Plenty of speed but no KV headroom for a 900-token prompt.
        let tight = [replica(3, 500, 12.0)];
        assert_eq!(
            c.decide(900, &spec(), &tight, LoadMode::Normal, 0),
            AdmissionDecision::Defer
        );
        let roomy = [replica(3, 5_000, 12.0)];
        assert_eq!(
            c.decide(900, &spec(), &roomy, LoadMode::Normal, 0),
            AdmissionDecision::Admit
        );
    }

    #[test]
    fn best_replica_wins() {
        // One saturated replica must not condemn the request when a
        // healthy one exists.
        let mut c = ctl();
        let r = [replica(500, 100, 0.5), replica(5, 60_000, 10.0)];
        assert_eq!(
            c.decide(300, &spec(), &r, LoadMode::Surge, 0),
            AdmissionDecision::Admit
        );
    }

    #[test]
    fn hysteresis_prevents_decision_flapping() {
        // Floor 0.35, hysteresis 0.1 → shed below 1.68 tok/s, recover
        // above 2.16 tok/s (for tds 4.8). A share oscillating inside the
        // band must not flip decisions.
        let mut c = ctl();
        let sp = spec();
        let shed = |tds: f64| [replica(300, 30_000, tds)];
        // Trip the latch.
        assert!(matches!(
            c.decide(200, &sp, &shed(1.6), LoadMode::Surge, 0),
            AdmissionDecision::Reject(_)
        ));
        // Oscillate inside the band: still shedding, every time.
        for _ in 0..10 {
            for tds in [1.75, 1.6, 2.0, 1.7] {
                assert!(
                    matches!(
                        c.decide(200, &sp, &shed(tds), LoadMode::Surge, 0),
                        AdmissionDecision::Reject(RejectReason::SurgeShed { .. })
                    ),
                    "flapped at share {tds}"
                );
            }
        }
        // Clear recovery past floor + hysteresis → admit again.
        assert_eq!(
            c.decide(200, &sp, &shed(2.3), LoadMode::Surge, 0),
            AdmissionDecision::Admit
        );
    }

    #[test]
    fn marginal_cost_and_predicted_qoe_scales() {
        let c = ctl();
        let r = replica(10, 1_000, 2.4);
        assert!((c.predicted_qoe(&r, &spec()) - 0.5).abs() < 1e-9);
        // 200 prompt + 260 expected output over 1000 free.
        assert!((c.marginal_cost(&r, 200) - 0.46).abs() < 1e-9);
        assert!((r.kv_utilization() - (1.0 - 1_000.0 / 70_000.0)).abs() < 1e-12);
    }

    #[test]
    fn no_replicas_rejects() {
        let mut c = ctl();
        assert!(matches!(
            c.decide(100, &spec(), &[], LoadMode::Normal, 0),
            AdmissionDecision::Reject(RejectReason::Saturated { .. })
        ));
    }
}
