//! Andes: a QoE-aware serving system for LLM-based text streaming services.
//!
//! Reproduction of Liu et al., "Andes: Defining and Enhancing
//! Quality-of-Experience in LLM-Based Text Streaming Services" (2024),
//! grown toward a production-scale serving stack. See DESIGN.md for the
//! full architecture and experiment index; ROADMAP.md for per-PR
//! quickstarts.
//!
//! # Module map
//!
//! The crate layers bottom-up; simulated and real execution share every
//! coordinator line:
//!
//! | Layer | Module | Role |
//! |---|---|---|
//! | L0 | [`util`] | PRNG, JSON, CLI, CSV, plotting, benchmarking, property testing (offline: no external crates beyond the `xla` closure) |
//! | L1 | [`model`] | LLM/GPU profiles and the calibrated latency model |
//! | L1 | [`qoe`] | QoE spec (TTFT/TDS), the Eq. 1 metric with incremental digest state, client token buffer |
//! | L1 | [`workload`] | datasets, arrival processes, QoE traces (incl. §6.1 price tiers), multi-turn sessions, record/replay CSV |
//! | L2 | [`backend`] | `ExecutionBackend` + `Clock`: calibrated simulator (virtual clock) and PJRT real model (wall clock) |
//! | L3 | [`coordinator`] | continuous-batching engine, block KV manager with session prefix parking, schedulers (FCFS / RR / Andes greedy / exact DP), metrics |
//! | L4 | [`cluster`] | elastic replica pool + routing policies (incl. session affinity), replica-seconds accounting |
//! | L4 | [`gateway`] | the QoE-aware front door: admission (tier-weighted), pacing, surge detection, predictive autoscaling, spill tier, multi-gateway federation |
//! | L4 | [`delivery`] | client-side delivery: per-request network model (jitter/loss/disconnects), client playback buffer with stall accounting, jitter-adaptive pacer lead |
//! | L5 | [`server`] | TCP streaming server (JSON lines) over the real tiny-OPT model or the simulator, with `/metrics` + `/health` on the same port |
//! | L5 | [`experiments`] | one entry per paper figure/table plus the `ext-*` extensions |
//! | — | [`telemetry`] | metric registry (Prometheus exposition), per-request event tracer (JSONL), leveled logging — the observation layer every subsystem reports into |
//! | — | [`analysis`] | in-tree determinism lint (`andes lint`): hand-rolled lexer + rules D1–D6 and the X1 metric-taxonomy cross-check, with inline suppressions and a ratcheting baseline |
//! | — | [`config`] | JSON deployment config: model, GPU, scheduler, engine, gateway, autoscale, spill, federation, tiers, sessions, telemetry |
//! | — | [`runtime`] | PJRT loading and byte-level tokenizer for the compiled tiny-OPT model |
//!
//! # The serving path
//!
//! A request enters through the [`gateway`] (or a federation of
//! gateways — [`gateway::federation`]), which admits, defers, or
//! rejects it against the current cluster state; admitted requests are
//! routed across [`cluster`] replicas, scheduled per-replica by a
//! [`coordinator`] scheduler, and their tokens are released at the
//! user's digestion speed by the gateway pacer. `andes exp <id|all>`
//! regenerates every paper artifact from this same stack.

pub mod util;
pub mod analysis;
pub mod backend;
pub mod cluster;
pub mod config;
pub mod delivery;
pub mod experiments;
pub mod gateway;
pub mod server;
pub mod coordinator;
pub mod model;
pub mod workload;
pub mod qoe;
pub mod runtime;
pub mod telemetry;
