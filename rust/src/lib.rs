//! Andes: a QoE-aware serving system for LLM-based text streaming services.
//!
//! Reproduction of Liu et al., "Andes: Defining and Enhancing
//! Quality-of-Experience in LLM-Based Text Streaming Services" (2024).
//! See DESIGN.md for the architecture and experiment index.

pub mod util;
pub mod backend;
pub mod cluster;
pub mod config;
pub mod experiments;
pub mod gateway;
pub mod server;
pub mod coordinator;
pub mod model;
pub mod workload;
pub mod qoe;
pub mod runtime;
