//! Text-streaming service demo: spin up the TCP server (real tiny-OPT
//! model over PJRT), connect a client, stream tokens through the
//! client-side token buffer (paper §5, Fig. 8), and print the pacing.
//!
//! Requires `make artifacts`.
//!
//! Usage: cargo run --release --example streaming_client

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::mpsc::channel;

use andes::qoe::buffer::TokenBuffer;
use andes::qoe::spec::QoeSpec;
use andes::server::{serve, ServerConfig};
use andes::util::json::Json;

fn main() -> anyhow::Result<()> {
    // Server thread on an ephemeral port.
    let (ready_tx, ready_rx) = channel();
    std::thread::spawn(move || {
        let cfg = ServerConfig { addr: "127.0.0.1:0".into(), ..ServerConfig::default() };
        if let Err(e) = serve(cfg, Some(ready_tx)) {
            eprintln!("server error: {e:#}");
        }
    });
    let addr = ready_rx.recv()?;
    eprintln!("server up on {addr}");

    let spec = QoeSpec::new(0.5, 8.0); // pace display at 8 tok/s
    let mut stream = TcpStream::connect(&addr)?;
    let req = Json::obj(vec![
        ("prompt", "Stream me a story about patient schedulers".into()),
        ("max_tokens", 40u64.into()),
        ("ttft", spec.ttft.into()),
        ("tds", spec.tds.into()),
    ]);
    writeln!(stream, "{req}")?;

    let reader = BufReader::new(stream.try_clone()?);
    let mut buffer = TokenBuffer::new(&spec);
    // lint:allow(D2, example client measures live stream latency against a running server)
    let start = std::time::Instant::now();
    println!("--- streaming (buffer paces display at {} tok/s) ---", spec.tds);
    for line in reader.lines() {
        let line = line?;
        let ev = Json::parse(&line)?;
        match ev.get("event").as_str() {
            Some("token") => {
                let t = start.elapsed().as_secs_f64();
                let display_at = buffer.push(t);
                let text = ev.get("text").as_str().unwrap_or("").to_string();
                println!(
                    "t={t:6.3}s  recv token {:>2}  display_at={display_at:6.3}s  buffer_depth={}",
                    ev.get("index").as_u64().unwrap_or(0),
                    buffer.depth_at(t),
                );
                let _ = text;
            }
            Some("done") => {
                println!(
                    "--- done: {} tokens, server ttft {:.3}s, server-side QoE {:.3} ---",
                    ev.get("tokens").as_u64().unwrap_or(0),
                    ev.get("ttft").as_f64().unwrap_or(f64::NAN),
                    ev.get("qoe").as_f64().unwrap_or(f64::NAN),
                );
                break;
            }
            Some("error") => {
                eprintln!("server error: {}", ev.get("message").as_str().unwrap_or(""));
                break;
            }
            _ => {}
        }
    }
    // Verify the buffer produced a smooth display timeline.
    let displays = buffer.display_times();
    let min_gap = displays
        .windows(2)
        .map(|w| w[1] - w[0])
        .fold(f64::INFINITY, f64::min);
    println!(
        "display pacing: {} tokens, min inter-token gap {:.3}s (target ≥ {:.3}s)",
        displays.len(),
        min_gap,
        1.0 / spec.tds - 1e-9
    );
    Ok(())
}
