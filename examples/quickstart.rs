//! Quickstart: serve real batched requests through the full stack.
//!
//! Loads the AOT-compiled tiny-OPT model (JAX + Pallas → HLO → PJRT),
//! drives it with the Andes QoE-aware engine, streams the generated
//! text through the client-side token buffer, and reports per-request
//! TTFT / QoE plus system throughput.
//!
//! Run: `make artifacts && cargo run --release --example quickstart`

use andes::backend::pjrt::PjrtBackend;
use andes::backend::WallClock;
use andes::coordinator::engine::{Engine, EngineConfig};
use andes::coordinator::sched::andes::AndesScheduler;
use andes::model::gpu::a100_1x;
use andes::model::latency::LatencyModel;
use andes::model::llm::tiny_opt;
use andes::qoe::spec::QoeSpec;
use andes::runtime::engine::ModelRuntime;
use andes::runtime::tokenizer::ByteTokenizer;
use andes::runtime::Sampling;
use andes::workload::RequestSpec;

fn main() -> anyhow::Result<()> {
    let dir = ModelRuntime::default_dir();
    eprintln!("loading artifacts from {} ...", dir.display());
    let runtime = ModelRuntime::load(&dir)?;
    eprintln!(
        "platform={} model={} layers={} d_model={} ctx={}",
        runtime.platform(),
        "tiny-opt",
        runtime.meta.n_layers,
        runtime.meta.d_model,
        runtime.meta.max_seq
    );

    let tokenizer = ByteTokenizer::new();
    let backend = PjrtBackend::new(runtime, Sampling::TopK { k: 40, temperature: 1.0 }, 7);

    // A deliberately small KV budget so the scheduler has real work.
    let cfg = EngineConfig {
        kv_capacity_tokens: 2048,
        swap_capacity_tokens: 8192,
        max_output_tokens: 96,
        ..EngineConfig::default()
    };
    // The latency model is only used for scheduling predictions here;
    // actual latencies are wall-clock.
    let latency = LatencyModel::for_deployment(&tiny_opt(), &a100_1x());
    let mut engine = Engine::new(
        cfg,
        backend,
        WallClock::new(),
        Box::new(AndesScheduler::with_defaults()),
        latency,
    );

    let prompts = [
        "Explain the Andes mountain range to a curious child.",
        "Write a haiku about token streaming.",
        "Why do users dislike waiting for chatbots?",
        "Describe quality of experience in one sentence.",
        "What makes continuous batching efficient?",
        "Tell me a story about a scheduler that cared.",
        "Summarize the benefits of client-side buffering.",
        "How fast can people actually read?",
    ];
    for (i, p) in prompts.iter().enumerate() {
        let prompt_tokens = tokenizer.encode(p);
        // Submit via the typed API so the backend gets real token ids.
        let spec = RequestSpec {
            id: i,
            arrival: 0.0,
            prompt_tokens: prompt_tokens.len(),
            output_tokens: 48 + (i * 8) % 40,
            qoe: QoeSpec::new(0.5, 4.8),
            session: None,
        };
        engine.submit_with_prompt(spec, prompt_tokens)?;
    }

    while engine.has_work() {
        engine.tick()?;
    }

    let m = engine.metrics();
    println!("\n=== per-request results ===");
    for r in &m.requests {
        println!(
            "req {:>2}: prompt={:>3} tok, output={:>3} tok, ttft={:>6.3}s, qoe={:.3}, preempts={}",
            r.id, r.prompt_tokens, r.output_tokens, r.ttft, r.final_qoe, r.preemptions
        );
    }
    println!("\n=== system ===");
    println!("{}", m.summary());
    println!(
        "elapsed={:.2}s tokens={} throughput={:.1} tok/s",
        m.elapsed(),
        m.total_tokens,
        m.throughput()
    );
    Ok(())
}
