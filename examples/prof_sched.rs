//! profile helper: hammer the andes scheduler at N=1000
use andes::coordinator::kv::KvCacheManager;
use andes::coordinator::request::{Phase, Request, RequestId};
use andes::coordinator::sched::andes::AndesScheduler;
use andes::coordinator::sched::{SchedView, Scheduler};
use andes::model::gpu::a100_4x;
use andes::model::latency::LatencyModel;
use andes::model::llm::opt_66b;
use andes::qoe::spec::QoeSpec;
use andes::util::rng::Rng;

fn main() {
    let n = 1000;
    let mut rng = Rng::new(42);
    let latency = LatencyModel::for_deployment(&opt_66b(), &a100_4x());
    let mut kv = KvCacheManager::new(70_000, 100_000, 16);
    let mut requests = Vec::with_capacity(n);
    let active: Vec<RequestId> = (0..n).collect();
    for id in 0..n {
        let prompt = rng.range(50, 600);
        let mut r = Request::new(id, rng.f64() * 10.0, prompt, QoeSpec::new(1.0, 4.8));
        if id % 2 == 0 && kv.allocate(id, r.context_len()).is_ok() {
            r.phase = Phase::Running;
            for k in 0..rng.range(1, 60) {
                r.deliver_token(r.arrival + 1.0 + k as f64 * 0.15);
            }
        }
        requests.push(r);
    }
    let view = SchedView {
        now: 30.0, horizon: 50.0, requests: &requests, active: &active,
        kv: &kv, latency: &latency, total_requests_seen: n, total_preemptions: 0,
        slack: None,
    };
    for grid in [1usize, 2, 4, 8, 16] {
        let mut s = AndesScheduler::new(andes::coordinator::sched::andes::AndesConfig {
            b_grid: grid,
            ..Default::default()
        });
        // lint:allow(D2, example profiles scheduler throughput against the wall clock)
        let t0 = std::time::Instant::now();
        let mut acc = 0usize;
        let iters = 500;
        for _ in 0..iters {
            acc += s.schedule(&view).len();
        }
        println!("b_grid={grid}: {:.3} ms/call (acc {acc})", t0.elapsed().as_secs_f64()*1e3/iters as f64);
    }
}
// (appended) grid-scaling probe lives in main2 — not used
