//! Smoke test: the AOT bridge in isolation — lower a Pallas matmul with
//! gen_hlo-style tooling, load the HLO text via the xla crate, execute
//! on the PJRT CPU client, and check the numbers.
//!
//! Usage: python /opt/xla-example/gen_hlo.py /tmp/fn_hlo.txt --pallas
//!        cargo run --release --example smoke
fn main() -> anyhow::Result<()> {
    let path = std::env::args().nth(1).unwrap_or_else(|| "/tmp/fn_hlo.txt".to_string());
    if !std::path::Path::new(&path).exists() {
        eprintln!("{path} missing — generate it with gen_hlo.py (see header)");
        return Ok(());
    }
    let client = xla::PjRtClient::cpu()?;
    let proto = xla::HloModuleProto::from_text_file(&path)?;
    let comp = xla::XlaComputation::from_proto(&proto);
    let exe = client.compile(&comp)?;
    let x = xla::Literal::vec1(&[1f32, 2., 3., 4.]).reshape(&[2, 2])?;
    let y = xla::Literal::vec1(&[1f32, 1., 1., 1.]).reshape(&[2, 2])?;
    let r = exe.execute::<xla::Literal>(&[x, y])?[0][0].to_literal_sync()?.to_tuple1()?;
    let values = r.to_vec::<f32>()?;
    println!("matmul+2 result: {values:?}");
    assert_eq!(values, vec![5f32, 5., 9., 9.]);
    println!("smoke OK");
    Ok(())
}
