//! Capacity planning: for a chosen deployment, sweep request rates to
//! find the maximum rate each scheduler sustains at avg QoE ≥ 0.9 (the
//! paper's "system capacity" metric), and report the cost-per-request
//! implication.
//!
//! Usage: cargo run --release --example capacity_planning -- [model] [dataset]
//!   model:   opt-13b | opt-30b | opt-66b | opt-175b   (default opt-66b)
//!   dataset: sharegpt | multiround                    (default sharegpt)

use andes::experiments::runner::{
    capacity_at_threshold, estimate_capacity, rate_grid, SchedKind, SimRun,
};
use andes::model::gpu::{a100_1x, a100_4x};
use andes::model::llm::llm_by_name;
use andes::workload::{ArrivalProcess, Dataset, QoeTrace};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let model = args.first().map(|s| s.as_str()).unwrap_or("opt-66b");
    let dataset = args
        .get(1)
        .and_then(|s| Dataset::by_name(s))
        .unwrap_or(Dataset::ShareGpt);
    let llm = llm_by_name(model).expect("unknown model");
    let gpu = if llm.name == "OPT-13B" { a100_1x() } else { a100_4x() };
    println!(
        "capacity planning: {} on {} serving {} (QoE threshold 0.9)\n",
        llm.name,
        gpu.name,
        dataset.name()
    );

    let est = estimate_capacity(&llm, &gpu, dataset);
    let rates = rate_grid(est, false);
    println!("analytic capacity estimate: {est:.2} req/s; sweeping {rates:?}\n");

    let mut capacities = Vec::new();
    for sched in SchedKind::paper_three() {
        let mut series = Vec::new();
        print!("{:<12}", sched.label());
        for &rate in &rates {
            let m = SimRun {
                llm: llm.clone(),
                gpu: gpu.clone(),
                sched: sched.clone(),
                dataset,
                arrivals: ArrivalProcess::Poisson { rate },
                qoe_trace: QoeTrace::TextReading,
                num_requests: 1200,
                seed: 7,
            }
            .execute();
            print!(" {:.2}@{rate:.1}", m.avg_qoe());
            series.push((rate, m.avg_qoe()));
        }
        let cap = capacity_at_threshold(&series, 0.9);
        println!("  → capacity {cap:.2} req/s");
        capacities.push((sched.label(), cap));
    }
    let fcfs = capacities.iter().find(|c| c.0 == "vLLM-FCFS").unwrap().1;
    let andes = capacities.iter().find(|c| c.0 == "Andes").unwrap().1;
    if fcfs > 0.0 {
        println!(
            "\nAndes sustains {:.2}× the request rate of vLLM-FCFS at equal QoE;\n\
             equivalently, cost per request drops to {:.0}% of the FCFS baseline.",
            andes / fcfs,
            100.0 * fcfs / andes
        );
    }
}
