//! Compare schedulers (FCFS vs Round-Robin vs Andes) on a simulated
//! OPT-66B / 4×A100 deployment across request rates.
//!
//! Usage: cargo run --release --example compare_schedulers -- 3 4 5
use andes::backend::sim::SimBackend;
use andes::backend::VirtualClock;
use andes::coordinator::engine::{Engine, EngineConfig};
use andes::coordinator::sched::andes::AndesScheduler;
use andes::coordinator::sched::fcfs::FcfsScheduler;
use andes::coordinator::sched::Scheduler;
use andes::model::gpu::a100_4x;
use andes::model::latency::LatencyModel;
use andes::model::llm::opt_66b;
use andes::util::stats::{mean, percentile};
use andes::workload::{ArrivalProcess, Dataset, QoeTrace, Workload};

fn run(sched: Box<dyn Scheduler>, rate: f64) {
    let llm = opt_66b();
    let gpu = a100_4x();
    let latency = LatencyModel::for_deployment(&llm, &gpu);
    let cfg = EngineConfig {
        kv_capacity_tokens: llm.kv_capacity_tokens(&gpu),
        swap_capacity_tokens: llm.swap_capacity_tokens(&gpu),
        ..EngineConfig::default()
    };
    let name = sched.name().to_string();
    let mut e = Engine::new(cfg, SimBackend::new(latency.clone()), VirtualClock::default(), sched, latency);
    let wl = Workload {
        dataset: Dataset::ShareGpt,
        arrivals: ArrivalProcess::Poisson { rate },
        qoe_trace: QoeTrace::TextReading,
        num_requests: 1500,
        seed: 42,
    };
    e.load_trace(wl.generate());
    let m = e.run_to_completion().unwrap();
    let ttfts = m.ttfts();
    let iters = &m.iterations;
    let decode_iters: Vec<_> = iters.iter().filter(|s| !s.is_prefill).collect();
    let avg_b = mean(&decode_iters.iter().map(|s| s.batch_size as f64).collect::<Vec<_>>());
    let prefill_time: f64 = iters.iter().filter(|s| s.is_prefill).map(|s| s.latency).sum();
    let decode_time: f64 = decode_iters.iter().map(|s| s.latency).sum();
    println!(
        "rate={rate:.1} {name:<7} qoe={:.3} p10qoe={:.2} ttft p50={:.1} p90={:.1} tds p50={:.2} tput={:.0} B~{:.0} pre/req={:.2} (swap {} rec {} oom {}) pf_time={:.0}s dec_time={:.0}s",
        m.avg_qoe(),
        percentile(&m.qoes(), 10.0),
        percentile(&ttfts, 50.0),
        percentile(&ttfts, 90.0),
        percentile(&m.tds_values(), 50.0),
        m.throughput(),
        avg_b,
        m.preemption_frequency(),
        m.swap_preemptions,
        m.recompute_preemptions,
        m.oom_preemptions,
        prefill_time,
        decode_time,
    );
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let rates: Vec<f64> = if args.len() > 1 {
        args[1..].iter().map(|a| a.parse().unwrap()).collect()
    } else {
        vec![2.0, 3.0, 4.0]
    };
    for &rate in &rates {
        run(Box::new(FcfsScheduler::new()), rate);
        run(Box::new(AndesScheduler::with_defaults()), rate);
        println!();
    }
}
