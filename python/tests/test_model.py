"""L2 correctness: tiny-OPT model shapes and KV-cache consistency."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M

jax.config.update("jax_platform_name", "cpu")

CFG = M.CONFIG


@pytest.fixture(scope="module")
def params():
    return M.init_params()


def make_prompt(b, lengths):
    tokens = jnp.zeros((b, CFG.max_seq), jnp.int32)
    for row, ln in enumerate(lengths):
        tokens = tokens.at[row, :ln].set((jnp.arange(ln) % 250) + 2)
    return tokens


class TestPrefill:
    def test_shapes(self, params):
        tokens = make_prompt(2, [5, 9])
        logits, k, v = M.prefill(params, tokens, jnp.array([5, 9], jnp.int32))
        assert logits.shape == (2, CFG.vocab)
        assert k.shape == (CFG.n_layers, 2, CFG.n_heads, CFG.max_seq, CFG.d_head)
        assert v.shape == k.shape
        assert not np.any(np.isnan(np.asarray(logits)))

    def test_last_position_indexing(self, params):
        """Per-row logits must come from each row's own last position."""
        tokens = make_prompt(2, [5, 9])
        lengths = jnp.array([5, 9], jnp.int32)
        logits, _, _ = M.prefill(params, tokens, lengths)
        # Row 0 alone must produce identical logits.
        l0, _, _ = M.prefill(params, tokens[:1], lengths[:1])
        np.testing.assert_allclose(np.asarray(logits)[0], np.asarray(l0)[0], rtol=2e-4, atol=2e-4)

    def test_padding_does_not_leak(self, params):
        """Garbage beyond `length` must not change the last-position logits."""
        t1 = make_prompt(1, [6])
        t2 = t1.at[0, 6:].set(99)
        lengths = jnp.array([6], jnp.int32)
        l1, _, _ = M.prefill(params, t1, lengths)
        l2, _, _ = M.prefill(params, t2, lengths)
        np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), rtol=1e-5, atol=1e-5)


class TestDecode:
    def test_matches_prefill_extension(self, params):
        """decode_step(tok at pos p) == prefill over the extended prompt."""
        tokens = make_prompt(2, [5, 3])
        lengths = jnp.array([5, 3], jnp.int32)
        logits, k, v = M.prefill(params, tokens, lengths)
        nxt = jnp.argmax(logits, -1).astype(jnp.int32)
        d_logits, k2, v2 = M.decode_step(params, nxt, lengths, k, v)
        ext = tokens.at[0, 5].set(nxt[0]).at[1, 3].set(nxt[1])
        ref_logits, _, _ = M.prefill(params, ext, lengths + 1)
        np.testing.assert_allclose(
            np.asarray(d_logits), np.asarray(ref_logits), rtol=5e-4, atol=5e-4
        )

    def test_multi_step_chain(self, params):
        """Three decode steps equal one prefill of the full sequence."""
        tokens = make_prompt(1, [4])
        lengths = jnp.array([4], jnp.int32)
        logits, k, v = M.prefill(params, tokens, lengths)
        seq = list(np.asarray(tokens)[0][:4])
        pos = 4
        for _ in range(3):
            nxt = jnp.argmax(logits, -1).astype(jnp.int32)
            seq.append(int(nxt[0]))
            logits, k, v = M.decode_step(params, nxt, jnp.array([pos], jnp.int32), k, v)
            pos += 1
        full = jnp.zeros((1, CFG.max_seq), jnp.int32).at[0, :len(seq)].set(jnp.array(seq))
        ref_logits, _, _ = M.prefill(params, full, jnp.array([len(seq)], jnp.int32))
        np.testing.assert_allclose(
            np.asarray(logits), np.asarray(ref_logits), rtol=1e-3, atol=1e-3
        )

    def test_cache_write_isolated_per_row(self, params):
        """A decode write at row 0's position must not disturb row 1."""
        tokens = make_prompt(2, [5, 7])
        lengths = jnp.array([5, 7], jnp.int32)
        _, k, v = M.prefill(params, tokens, lengths)
        toks = jnp.array([10, 11], jnp.int32)
        _, k2, _ = M.decode_step(params, toks, lengths, k, v)
        # Row 1's cache at positions < 7 unchanged.
        np.testing.assert_array_equal(
            np.asarray(k)[:, 1, :, :7], np.asarray(k2)[:, 1, :, :7]
        )
        # Row 0 slot 5 was written.
        assert np.abs(np.asarray(k2)[:, 0, :, 5] - np.asarray(k)[:, 0, :, 5]).max() > 0


def test_params_deterministic():
    a = M.init_params(seed=0)
    b = M.init_params(seed=0)
    np.testing.assert_array_equal(np.asarray(a["tok_embed"]), np.asarray(b["tok_embed"]))
    c = M.init_params(seed=1)
    assert np.abs(np.asarray(a["tok_embed"]) - np.asarray(c["tok_embed"])).max() > 0
