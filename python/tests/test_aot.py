"""AOT artifact smoke tests: HLO text generation and metadata."""

import json
import os

import pytest

from compile import aot


def test_prefill_lowering_has_real_constants(tmp_path):
    text = aot.lower_prefill(batch=1, seed=0)
    assert "HloModule" in text
    # Weights must be baked (not elided as `constant({...})`).
    assert "constant({...})" not in text
    assert "f32[512,128]" in text  # tok_embed
    assert len(text) > 1_000_000


def test_decode_lowering_signature():
    text = aot.lower_decode(batch=2, seed=0)
    assert "s32[2]" in text          # tokens/positions
    assert "f32[4,2,8,256,16]" in text  # KV cache
    assert "f32[2,512]" in text      # logits


def test_meta_roundtrip(tmp_path):
    aot.write_meta(str(tmp_path))
    with open(tmp_path / "meta.json") as f:
        meta = json.load(f)
    assert meta["vocab"] == 512
    assert meta["max_seq"] == 256
    assert 1 in meta["decode_batches"]


def test_artifacts_dir_if_present():
    """If `make artifacts` has run, check the inventory is complete."""
    art = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
    if not os.path.isdir(art) or not os.path.exists(os.path.join(art, "meta.json")):
        pytest.skip("artifacts not built")
    with open(os.path.join(art, "meta.json")) as f:
        meta = json.load(f)
    for b in meta["prefill_batches"]:
        assert os.path.exists(os.path.join(art, f"prefill_b{b}.hlo.txt"))
    for b in meta["decode_batches"]:
        assert os.path.exists(os.path.join(art, f"decode_b{b}.hlo.txt"))


def test_perf_estimate_within_vmem():
    """Tile choices must stay within the VMEM budget at every profiled
    shape (the assertion inside the estimator enforces it)."""
    from compile.perf_estimate import decode_estimate, prefill_estimate

    for (b, h, s, d) in [(1, 8, 256, 16), (64, 32, 2048, 128)]:
        for est in (decode_estimate(b, h, s, d), prefill_estimate(b, h, s, d)):
            assert est["vmem_frac"] < 0.5
            assert est["est_time_us"] > 0
    # Decode is memory-bound, long-context prefill compute-bound.
    assert decode_estimate(8, 8, 2048, 64)["bound"] == "memory"
    assert prefill_estimate(8, 8, 2048, 64)["bound"] == "compute"
