"""L1 correctness: Pallas kernels vs pure-jnp oracles.

The CORE correctness signal for the compiled artifacts: hypothesis
sweeps shapes, dtypes, and cache lengths; assert_allclose against
ref.py.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.attention import KV_TILE, decode_attention, prefill_attention
from compile.kernels.ref import decode_attention_ref, prefill_attention_ref

jax.config.update("jax_platform_name", "cpu")


def rand(key, shape, dtype):
    x = jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)
    return x.astype(dtype)


TOLS = {jnp.float32: dict(rtol=2e-5, atol=2e-5), jnp.bfloat16: dict(rtol=3e-2, atol=3e-2)}


class TestDecodeAttention:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize("s", [KV_TILE, 2 * KV_TILE, 4 * KV_TILE])
    def test_matches_ref_full_cache(self, dtype, s):
        b, h, d = 2, 4, 16
        q = rand(0, (b, h, d), dtype)
        k = rand(1, (b, h, s, d), dtype)
        v = rand(2, (b, h, s, d), dtype)
        lens = jnp.full((b,), s, jnp.int32)
        got = decode_attention(q, k, v, lens)
        ref = decode_attention_ref(q, k, v, lens)
        np.testing.assert_allclose(
            np.asarray(got, np.float32), np.asarray(ref, np.float32), **TOLS[dtype]
        )

    def test_partial_cache_masking(self):
        """Entries beyond cache_len must not affect the output."""
        b, h, s, d = 3, 2, 2 * KV_TILE, 8
        q = rand(3, (b, h, d), jnp.float32)
        k = rand(4, (b, h, s, d), jnp.float32)
        v = rand(5, (b, h, s, d), jnp.float32)
        lens = jnp.array([1, 7, 130], jnp.int32)
        got = decode_attention(q, k, v, lens)
        # Corrupt the masked region; result must be identical.
        k2 = k.at[:, :, 200:].set(1e9)
        v2 = v.at[:, :, 200:].set(-1e9)
        lens_ok = jnp.array([1, 7, 130], jnp.int32)
        got2 = decode_attention(q, k2, v2, lens_ok)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(got2))
        ref = decode_attention_ref(q, k, v, lens)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref), rtol=2e-5, atol=2e-5)

    def test_single_valid_entry_is_value_passthrough(self):
        """With cache_len == 1 the output equals v[0] exactly (softmax of 1)."""
        b, h, s, d = 1, 2, KV_TILE, 4
        q = rand(6, (b, h, d), jnp.float32)
        k = rand(7, (b, h, s, d), jnp.float32)
        v = rand(8, (b, h, s, d), jnp.float32)
        got = decode_attention(q, k, v, jnp.array([1], jnp.int32))
        np.testing.assert_allclose(
            np.asarray(got)[0], np.asarray(v)[0, :, 0], rtol=1e-6, atol=1e-6
        )

    @settings(max_examples=25, deadline=None)
    @given(
        b=st.integers(1, 4),
        h=st.integers(1, 4),
        tiles=st.integers(1, 3),
        d=st.sampled_from([4, 8, 16, 32]),
        seed=st.integers(0, 2**16),
        data=st.data(),
    )
    def test_hypothesis_sweep(self, b, h, tiles, d, seed, data):
        s = tiles * KV_TILE
        lens = jnp.array(
            data.draw(st.lists(st.integers(1, s), min_size=b, max_size=b)), jnp.int32
        )
        q = rand(seed, (b, h, d), jnp.float32)
        k = rand(seed + 1, (b, h, s, d), jnp.float32)
        v = rand(seed + 2, (b, h, s, d), jnp.float32)
        got = decode_attention(q, k, v, lens)
        ref = decode_attention_ref(q, k, v, lens)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref), rtol=3e-5, atol=3e-5)
        assert not np.any(np.isnan(np.asarray(got)))


class TestPrefillAttention:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize("s", [KV_TILE, 2 * KV_TILE])
    def test_matches_ref(self, dtype, s):
        b, h, d = 2, 2, 16
        q = rand(10, (b, h, s, d), dtype)
        k = rand(11, (b, h, s, d), dtype)
        v = rand(12, (b, h, s, d), dtype)
        got = prefill_attention(q, k, v)
        ref = prefill_attention_ref(q, k, v)
        np.testing.assert_allclose(
            np.asarray(got, np.float32), np.asarray(ref, np.float32), **TOLS[dtype]
        )

    def test_causality(self):
        """Future positions must not influence earlier outputs."""
        b, h, s, d = 1, 2, 2 * KV_TILE, 8
        q = rand(13, (b, h, s, d), jnp.float32)
        k = rand(14, (b, h, s, d), jnp.float32)
        v = rand(15, (b, h, s, d), jnp.float32)
        out1 = np.asarray(prefill_attention(q, k, v))
        # Change the last 10 positions of k/v: outputs before S-10 fixed.
        k2 = k.at[:, :, -10:].add(3.0)
        v2 = v.at[:, :, -10:].add(-5.0)
        out2 = np.asarray(prefill_attention(q, k2, v2))
        np.testing.assert_array_equal(out1[:, :, : s - 10], out2[:, :, : s - 10])
        assert np.abs(out1[:, :, -1] - out2[:, :, -1]).max() > 1e-3

    def test_first_position_is_v0(self):
        b, h, s, d = 1, 1, KV_TILE, 4
        q = rand(16, (b, h, s, d), jnp.float32)
        k = rand(17, (b, h, s, d), jnp.float32)
        v = rand(18, (b, h, s, d), jnp.float32)
        out = prefill_attention(q, k, v)
        np.testing.assert_allclose(
            np.asarray(out)[0, 0, 0], np.asarray(v)[0, 0, 0], rtol=1e-6, atol=1e-6
        )

    @settings(max_examples=15, deadline=None)
    @given(
        b=st.integers(1, 3),
        h=st.integers(1, 3),
        tiles=st.integers(1, 2),
        d=st.sampled_from([4, 16]),
        seed=st.integers(0, 2**16),
    )
    def test_hypothesis_sweep(self, b, h, tiles, d, seed):
        s = tiles * KV_TILE
        q = rand(seed, (b, h, s, d), jnp.float32)
        k = rand(seed + 1, (b, h, s, d), jnp.float32)
        v = rand(seed + 2, (b, h, s, d), jnp.float32)
        got = prefill_attention(q, k, v)
        ref = prefill_attention_ref(q, k, v)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref), rtol=3e-5, atol=3e-5)
        assert not np.any(np.isnan(np.asarray(got)))


def test_shape_validation():
    with pytest.raises(AssertionError):
        decode_attention(
            jnp.zeros((1, 1, 4)),
            jnp.zeros((1, 1, 100, 4)),  # not a KV_TILE multiple
            jnp.zeros((1, 1, 100, 4)),
            jnp.array([1], jnp.int32),
        )
