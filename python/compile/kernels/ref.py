"""Pure-jnp oracles for the Pallas kernels (the correctness contract).

These implementations are deliberately naive — materialize the full
score matrix, mask, softmax — so they are easy to audit. pytest compares
the Pallas kernels against them across shapes/dtypes (hypothesis).
"""

import jax.numpy as jnp


def decode_attention_ref(q, k_cache, v_cache, cache_lens):
    """Reference single-query attention over a padded KV cache.

    q: [B, H, d]; k_cache/v_cache: [B, H, S, d]; cache_lens: [B].
    Returns [B, H, d].
    """
    _, _, s, d = k_cache.shape
    scale = 1.0 / (d**0.5)
    scores = jnp.einsum(
        "bhd,bhsd->bhs", q.astype(jnp.float32), k_cache.astype(jnp.float32)
    ) * scale
    idx = jnp.arange(s)[None, None, :]
    valid = idx < cache_lens[:, None, None]
    scores = jnp.where(valid, scores, -jnp.inf)
    w = jnp.exp(scores - jnp.max(scores, axis=-1, keepdims=True))
    w = w / jnp.sum(w, axis=-1, keepdims=True)
    out = jnp.einsum("bhs,bhsd->bhd", w, v_cache.astype(jnp.float32))
    return out.astype(q.dtype)


def prefill_attention_ref(q, k, v):
    """Reference causal self-attention. q/k/v: [B, H, S, d]."""
    _, _, s, _ = q.shape
    d = q.shape[-1]
    scale = 1.0 / (d**0.5)
    scores = jnp.einsum(
        "bhqd,bhkd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32)
    ) * scale
    causal = jnp.tril(jnp.ones((s, s), bool))
    scores = jnp.where(causal[None, None], scores, -jnp.inf)
    w = jnp.exp(scores - jnp.max(scores, axis=-1, keepdims=True))
    w = w / jnp.sum(w, axis=-1, keepdims=True)
    out = jnp.einsum("bhqk,bhkd->bhqd", w, v.astype(jnp.float32))
    return out.astype(q.dtype)
