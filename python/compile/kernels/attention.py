"""L1: Pallas attention kernels for the tiny-OPT serving model.

Two kernels cover the serving hot path:

- :func:`decode_attention` — single-query attention against a KV cache
  (the decode phase): for each batch row, one query vector attends over
  ``cache_len`` valid KV entries out of a fixed-size cache.
- :func:`prefill_attention` — causal self-attention over the whole
  prompt (the prefill phase), tiled flash-style.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the paper's
serving substrate (vLLM) implements these as CUDA PagedAttention
kernels tiled for threadblocks/warps over HBM/shared memory. On TPU the
same insight — keep the query resident, stream KV tiles through fast
memory, accumulate online softmax — maps to: queries pinned in VMEM,
KV streamed tile-by-tile (``BlockSpec`` delivers one (batch, head)
slice per grid step; the inner loop walks KV tiles), per-tile
``q @ K^T`` shaped for the MXU with fp32 accumulation, and a
single-pass online-softmax accumulator so no [S, S] score matrix ever
materializes in VMEM.

All kernels run with ``interpret=True``: the CPU PJRT plugin cannot
execute Mosaic custom-calls, so interpret mode is the correctness path
(real-TPU lowering is compile-only here). Numerics are validated
against ``ref.py`` by pytest/hypothesis. VMEM/MXU estimates for real
TPU execution are documented in DESIGN.md §Perf.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Tile size along the KV-sequence axis. 128 matches the MXU systolic
# array edge and keeps the per-tile VMEM footprint small:
# K/V tiles are [128, head_dim] each.
KV_TILE = 128


def _decode_kernel(q_ref, k_ref, v_ref, len_ref, o_ref, *, seq_tiles: int):
    """Single-query online-softmax attention for one (batch, head).

    Refs (one grid step = one batch row × one head):
      q_ref:   [1, 1, 1, d]  — the query vector.
      k_ref:   [1, 1, S, d]  — KV cache slice for this row/head.
      v_ref:   [1, 1, S, d]
      len_ref: [1]           — number of valid cache entries.
      o_ref:   [1, 1, 1, d]  — attention output.
    """
    d = q_ref.shape[-1]
    q = q_ref[0, 0, 0, :].astype(jnp.float32) * (1.0 / (d**0.5))
    valid_len = len_ref[0]

    def tile_step(t, carry):
        m_prev, l_prev, acc_prev = carry
        start = t * KV_TILE
        k = k_ref[0, 0, pl.dslice(start, KV_TILE), :].astype(jnp.float32)
        v = v_ref[0, 0, pl.dslice(start, KV_TILE), :].astype(jnp.float32)
        # [KV_TILE] scores for this tile; MXU-friendly contraction.
        s = k @ q
        idx = start + jax.lax.iota(jnp.int32, KV_TILE)
        s = jnp.where(idx < valid_len, s, -jnp.inf)
        # Online softmax update; guard all-masked tiles against NaN.
        m_new = jnp.maximum(m_prev, jnp.max(s))
        m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        p = jnp.exp(s - m_safe)
        corr = jnp.where(jnp.isfinite(m_prev), jnp.exp(m_prev - m_safe), 0.0)
        l_new = l_prev * corr + jnp.sum(p)
        acc_new = acc_prev * corr + p @ v
        return m_new, l_new, acc_new

    m0 = jnp.float32(-jnp.inf)
    l0 = jnp.float32(0.0)
    acc0 = jnp.zeros((d,), jnp.float32)
    _, l, acc = jax.lax.fori_loop(0, seq_tiles, tile_step, (m0, l0, acc0))
    o_ref[0, 0, 0, :] = (acc / jnp.maximum(l, 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def decode_attention(q, k_cache, v_cache, cache_lens, *, interpret=True):
    """Batched decode attention.

    Args:
      q:          [B, H, d]    — one query per sequence per head.
      k_cache:    [B, H, S, d] — KV cache (padded to S).
      v_cache:    [B, H, S, d]
      cache_lens: [B] int32    — valid entries per sequence.

    Returns:
      [B, H, d] attention outputs.
    """
    b, h, s, d = k_cache.shape
    assert s % KV_TILE == 0, f"cache length {s} must be a multiple of {KV_TILE}"
    seq_tiles = s // KV_TILE

    kernel = functools.partial(_decode_kernel, seq_tiles=seq_tiles)
    out = pl.pallas_call(
        kernel,
        grid=(b, h),
        in_specs=[
            pl.BlockSpec((1, 1, 1, d), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, 1, s, d), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, 1, s, d), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1,), lambda i, j: (i,)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, d), lambda i, j: (i, j, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, 1, d), q.dtype),
        interpret=interpret,
    )(q[:, :, None, :], k_cache, v_cache, cache_lens)
    return out[:, :, 0, :]


def _prefill_kernel(q_ref, k_ref, v_ref, o_ref, *, seq_tiles: int):
    """Causal flash attention for one (batch, head): Q tile resident,
    K/V tiles streamed, online softmax, no [S, S] materialization.

    Refs: q_ref/k_ref/v_ref/o_ref: [1, 1, S, d].
    """
    d = q_ref.shape[-1]
    scale = 1.0 / (d**0.5)

    def q_tile_step(tq, _):
        q_start = tq * KV_TILE
        q = q_ref[0, 0, pl.dslice(q_start, KV_TILE), :].astype(jnp.float32) * scale
        q_idx = q_start + jax.lax.iota(jnp.int32, KV_TILE)

        def kv_tile_step(tk, carry):
            m_prev, l_prev, acc_prev = carry
            k_start = tk * KV_TILE
            k = k_ref[0, 0, pl.dslice(k_start, KV_TILE), :].astype(jnp.float32)
            v = v_ref[0, 0, pl.dslice(k_start, KV_TILE), :].astype(jnp.float32)
            s = q @ k.T  # [KV_TILE, KV_TILE] on the MXU
            k_idx = k_start + jax.lax.iota(jnp.int32, KV_TILE)
            causal = q_idx[:, None] >= k_idx[None, :]
            s = jnp.where(causal, s, -jnp.inf)
            m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
            m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
            p = jnp.exp(s - m_safe[:, None])
            corr = jnp.where(jnp.isfinite(m_prev), jnp.exp(m_prev - m_safe), 0.0)
            l_new = l_prev * corr + jnp.sum(p, axis=1)
            acc_new = acc_prev * corr[:, None] + p @ v
            return m_new, l_new, acc_new

        m0 = jnp.full((KV_TILE,), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((KV_TILE,), jnp.float32)
        acc0 = jnp.zeros((KV_TILE, d), jnp.float32)
        # Causality: only KV tiles up to and including this Q tile.
        _, l, acc = jax.lax.fori_loop(0, tq + 1, kv_tile_step, (m0, l0, acc0))
        o_ref[0, 0, pl.dslice(q_start, KV_TILE), :] = (
            acc / jnp.maximum(l, 1e-30)[:, None]
        ).astype(o_ref.dtype)
        return 0

    jax.lax.fori_loop(0, seq_tiles, q_tile_step, 0)


@functools.partial(jax.jit, static_argnames=("interpret",))
def prefill_attention(q, k, v, *, interpret=True):
    """Batched causal self-attention over full sequences.

    Args:
      q, k, v: [B, H, S, d] with S a multiple of KV_TILE.

    Returns:
      [B, H, S, d] attention outputs.
    """
    b, h, s, d = q.shape
    assert s % KV_TILE == 0, f"sequence {s} must be a multiple of {KV_TILE}"
    seq_tiles = s // KV_TILE
    kernel = functools.partial(_prefill_kernel, seq_tiles=seq_tiles)
    return pl.pallas_call(
        kernel,
        grid=(b, h),
        in_specs=[
            pl.BlockSpec((1, 1, s, d), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, 1, s, d), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, 1, s, d), lambda i, j: (i, j, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, s, d), lambda i, j: (i, j, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, s, d), q.dtype),
        interpret=interpret,
    )(q, k, v)
