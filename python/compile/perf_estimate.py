"""L1 performance estimation for the Pallas attention kernels.

Interpret-mode wallclock on CPU says nothing about TPU performance, so
— per DESIGN.md §7 — we estimate the quantities that do matter for a
real TPU deployment from the kernels' BlockSpec structure:

- **VMEM footprint** per grid step (must stay well under ~16 MiB/core);
- **arithmetic intensity** (flops / HBM byte) vs the TPU roofline ridge
  to classify each kernel as memory- or compute-bound;
- **MXU utilization ceiling**: fraction of the kernel's flops that are
  MXU-shaped (128-aligned matmul contractions) and the padding waste
  when head_dim < 128.

Usage: python -m compile.perf_estimate [--csv out.csv]
"""

import argparse

from .kernels.attention import KV_TILE

# TPU v4-ish reference numbers (order-of-magnitude roofline).
MXU_FLOPS = 275e12  # bf16 flops/s per chip
HBM_BW = 1.2e12  # bytes/s
VMEM_BYTES = 16 * 1024 * 1024
RIDGE = MXU_FLOPS / HBM_BW  # flops per byte at the roofline ridge


def decode_estimate(b, h, s, d, dtype_bytes=4):
    """Decode attention: one query row attends over the KV cache."""
    # Per grid step (one batch row × head): q [1,d] + one K,V tile pair
    # resident + accumulator. BlockSpec streams the [S,d] cache, but only
    # KV_TILE rows live in VMEM at a time with double buffering (×2).
    vmem = (
        d * dtype_bytes  # q
        + 2 * 2 * KV_TILE * d * dtype_bytes  # K,V tiles, double-buffered
        + d * 4  # fp32 accumulator
        + KV_TILE * 4  # scores
    )
    # Whole-kernel traffic and flops.
    bytes_hbm = b * h * (2 * s * d * dtype_bytes + 2 * d * dtype_bytes)
    flops = b * h * (2 * s * d + 2 * s * d)  # qK^T + pV
    intensity = flops / bytes_hbm
    # MXU shaping: contractions are [KV_TILE,d]@[d] matvecs — the MXU
    # processes them as 128×128 tiles; utilization ceiling is d/128 for
    # the contraction dim times 1/128 for the single query row unless
    # queries are batched per-core.
    mxu_ceiling = min(1.0, d / 128.0)
    time_memory = bytes_hbm / HBM_BW
    time_compute = flops / (MXU_FLOPS * max(mxu_ceiling, 1e-9))
    return {
        "kernel": "decode",
        "vmem_bytes": vmem,
        "vmem_frac": vmem / VMEM_BYTES,
        "intensity": intensity,
        "bound": "memory" if intensity < RIDGE else "compute",
        "mxu_ceiling": mxu_ceiling,
        "est_time_us": max(time_memory, time_compute) * 1e6,
    }


def prefill_estimate(b, h, s, d, dtype_bytes=4):
    """Prefill attention: causal flash over [S, d]."""
    # Per grid step: one Q tile + one K,V tile + accumulator + scores.
    vmem = (
        KV_TILE * d * dtype_bytes  # Q tile
        + 2 * 2 * KV_TILE * d * dtype_bytes  # K,V tiles double-buffered
        + KV_TILE * d * 4  # accumulator
        + KV_TILE * KV_TILE * 4  # score tile
    )
    n_tiles = s // KV_TILE
    # Causal: ~half the tile pairs are computed.
    pairs = n_tiles * (n_tiles + 1) // 2
    flops = b * h * pairs * (2 * KV_TILE * KV_TILE * d * 2)
    bytes_hbm = b * h * (3 * s * d + s * d) * dtype_bytes
    intensity = flops / bytes_hbm
    mxu_ceiling = min(1.0, d / 128.0)  # [128,d]@[d,128] contractions
    time_memory = bytes_hbm / HBM_BW
    time_compute = flops / (MXU_FLOPS * max(mxu_ceiling, 1e-9))
    return {
        "kernel": "prefill",
        "vmem_bytes": vmem,
        "vmem_frac": vmem / VMEM_BYTES,
        "intensity": intensity,
        "bound": "memory" if intensity < RIDGE else "compute",
        "mxu_ceiling": mxu_ceiling,
        "est_time_us": max(time_memory, time_compute) * 1e6,
    }


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--csv", default=None)
    args = ap.parse_args()

    rows = []
    print(f"TPU roofline ridge: {RIDGE:.0f} flops/byte; VMEM budget {VMEM_BYTES >> 20} MiB")
    print(f"{'kernel':<8} {'B':>3} {'H':>3} {'S':>5} {'d':>4} "
          f"{'VMEM':>9} {'int.':>7} {'bound':>7} {'MXU≤':>5} {'t est':>9}")
    for (b, h, s, d) in [(1, 8, 256, 16), (16, 8, 256, 16), (8, 8, 2048, 64),
                         (64, 32, 2048, 128)]:
        for est in (decode_estimate(b, h, s, d), prefill_estimate(b, h, s, d)):
            rows.append((b, h, s, d, est))
            print(f"{est['kernel']:<8} {b:>3} {h:>3} {s:>5} {d:>4} "
                  f"{est['vmem_bytes']/1024:>7.1f}Ki {est['intensity']:>7.1f} "
                  f"{est['bound']:>7} {est['mxu_ceiling']:>5.2f} "
                  f"{est['est_time_us']:>7.1f}µs")
            assert est["vmem_frac"] < 0.5, "tile choice busts the VMEM budget"

    if args.csv:
        with open(args.csv, "w") as f:
            f.write("kernel,b,h,s,d,vmem_bytes,intensity,bound,mxu_ceiling,est_time_us\n")
            for (b, h, s, d, e) in rows:
                f.write(f"{e['kernel']},{b},{h},{s},{d},{e['vmem_bytes']},"
                        f"{e['intensity']:.2f},{e['bound']},{e['mxu_ceiling']:.3f},"
                        f"{e['est_time_us']:.2f}\n")
        print(f"wrote {args.csv}")


if __name__ == "__main__":
    main()
