"""AOT lowering: JAX model (with Pallas kernels) → HLO text artifacts.

Run once at build time (`make artifacts`); the Rust runtime then loads
and executes the artifacts via the PJRT C API with Python nowhere on
the request path.

HLO **text** is the interchange format, not serialized HloModuleProto:
jax ≥ 0.5 emits protos with 64-bit instruction ids which the image's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Artifacts:
  artifacts/prefill_b{B}.hlo.txt   — (tokens[B,S], lengths[B])
                                     → (logits[B,V], k[L,B,H,S,d], v[…])
  artifacts/decode_b{B}.hlo.txt    — (tokens[B], positions[B], k, v)
                                     → (logits[B,V], k, v)
  artifacts/meta.json              — model dims, batch sizes, token ids.

Usage: python -m compile.aot --out-dir ../artifacts
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M

# Batch sizes compiled ahead of time. The Rust engine rounds each batch
# up to the nearest available executable and pads.
PREFILL_BATCHES = (1, 2, 4)
DECODE_BATCHES = (1, 2, 4, 8, 16)


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text(print_large_constants=True)


def lower_prefill(batch: int, seed: int) -> str:
    cfg = M.CONFIG
    fn = M.build_prefill_fn(seed=seed)
    tokens = jax.ShapeDtypeStruct((batch, cfg.max_seq), jnp.int32)
    lengths = jax.ShapeDtypeStruct((batch,), jnp.int32)
    return to_hlo_text(jax.jit(fn).lower(tokens, lengths))


def lower_decode(batch: int, seed: int) -> str:
    cfg = M.CONFIG
    fn = M.build_decode_fn(seed=seed)
    kv_shape = (cfg.n_layers, batch, cfg.n_heads, cfg.max_seq, cfg.d_head)
    tokens = jax.ShapeDtypeStruct((batch,), jnp.int32)
    positions = jax.ShapeDtypeStruct((batch,), jnp.int32)
    k = jax.ShapeDtypeStruct(kv_shape, jnp.float32)
    v = jax.ShapeDtypeStruct(kv_shape, jnp.float32)
    return to_hlo_text(jax.jit(fn).lower(tokens, positions, k, v))


def write_meta(out_dir: str) -> None:
    cfg = M.CONFIG
    meta = {
        "model": "tiny-opt",
        "vocab": cfg.vocab,
        "d_model": cfg.d_model,
        "n_layers": cfg.n_layers,
        "n_heads": cfg.n_heads,
        "d_head": cfg.d_head,
        "max_seq": cfg.max_seq,
        "pad_token": cfg.pad_token,
        "eos_token": cfg.eos_token,
        "prefill_batches": list(PREFILL_BATCHES),
        "decode_batches": list(DECODE_BATCHES),
        "prefill_inputs": ["tokens[i32 B,S]", "lengths[i32 B]"],
        "prefill_outputs": ["logits[f32 B,V]", "k[f32 L,B,H,S,d]", "v[f32 L,B,H,S,d]"],
        "decode_inputs": [
            "tokens[i32 B]",
            "positions[i32 B]",
            "k[f32 L,B,H,S,d]",
            "v[f32 L,B,H,S,d]",
        ],
        "decode_outputs": ["logits[f32 B,V]", "k[f32 L,B,H,S,d]", "v[f32 L,B,H,S,d]"],
    }
    with open(os.path.join(out_dir, "meta.json"), "w") as f:
        json.dump(meta, f, indent=2)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    for b in PREFILL_BATCHES:
        path = os.path.join(args.out_dir, f"prefill_b{b}.hlo.txt")
        text = lower_prefill(b, args.seed)
        with open(path, "w") as f:
            f.write(text)
        print(f"wrote {path} ({len(text)} chars)")
    for b in DECODE_BATCHES:
        path = os.path.join(args.out_dir, f"decode_b{b}.hlo.txt")
        text = lower_decode(b, args.seed)
        with open(path, "w") as f:
            f.write(text)
        print(f"wrote {path} ({len(text)} chars)")
    write_meta(args.out_dir)
    print(f"wrote {os.path.join(args.out_dir, 'meta.json')}")


if __name__ == "__main__":
    main()
