"""L2: tiny OPT-style decoder model (JAX), calling the L1 Pallas kernels.

This is the real model the Rust coordinator serves through PJRT: an
OPT-architecture decoder (pre-LN, ReLU MLP, learned positional
embeddings, tied LM head) at toy scale — 4 layers, d_model 128,
8 heads, vocab 512, context 256. The structure mirrors the OPT family
in the paper's Table 3; scale is what a CPU can decode interactively.

Two entry points are AOT-lowered (see aot.py):

- ``prefill``: full-prompt pass → last-position logits + KV caches.
- ``decode_step``: one token per sequence against the KV caches.

Weights are generated from a fixed PRNG seed at lowering time and baked
into the HLO as constants, making the artifacts self-contained.
"""

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from .kernels.attention import decode_attention, prefill_attention


class ModelConfig(NamedTuple):
    vocab: int = 512
    d_model: int = 128
    n_layers: int = 4
    n_heads: int = 8
    max_seq: int = 256
    ffn_mult: int = 4
    # Reserved token ids (byte tokens occupy 2..258).
    pad_token: int = 0
    eos_token: int = 1

    @property
    def d_head(self) -> int:
        return self.d_model // self.n_heads


CONFIG = ModelConfig()


def init_params(cfg: ModelConfig = CONFIG, seed: int = 0):
    """Initialize weights (scaled-normal, OPT-style shapes)."""
    key = jax.random.PRNGKey(seed)
    keys = iter(jax.random.split(key, 64))
    d, f = cfg.d_model, cfg.d_model * cfg.ffn_mult

    def dense(k, shape, scale):
        return (jax.random.normal(k, shape, jnp.float32) * scale).astype(jnp.float32)

    params = {
        "tok_embed": dense(next(keys), (cfg.vocab, d), 0.02),
        "pos_embed": dense(next(keys), (cfg.max_seq, d), 0.02),
        "ln_f_scale": jnp.ones((d,)),
        "ln_f_bias": jnp.zeros((d,)),
        "layers": [],
    }
    for _ in range(cfg.n_layers):
        params["layers"].append(
            {
                "ln1_scale": jnp.ones((d,)),
                "ln1_bias": jnp.zeros((d,)),
                "wq": dense(next(keys), (d, d), d**-0.5),
                "wk": dense(next(keys), (d, d), d**-0.5),
                "wv": dense(next(keys), (d, d), d**-0.5),
                "wo": dense(next(keys), (d, d), d**-0.5),
                "ln2_scale": jnp.ones((d,)),
                "ln2_bias": jnp.zeros((d,)),
                "w_up": dense(next(keys), (d, f), d**-0.5),
                "b_up": jnp.zeros((f,)),
                "w_down": dense(next(keys), (f, d), f**-0.5),
                "b_down": jnp.zeros((d,)),
            }
        )
    return params


def _layer_norm(x, scale, bias, eps=1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * scale + bias


def _split_heads(x, cfg):
    # [B, S, d_model] -> [B, H, S, d_head]
    b, s, _ = x.shape
    x = x.reshape(b, s, cfg.n_heads, cfg.d_head)
    return jnp.moveaxis(x, 2, 1)


def _merge_heads(x, cfg):
    # [B, H, S, d_head] -> [B, S, d_model]
    b, _, s, _ = x.shape
    return jnp.moveaxis(x, 1, 2).reshape(b, s, cfg.d_model)


def prefill(params, tokens, lengths, cfg: ModelConfig = CONFIG, interpret=True):
    """Full-prompt forward pass.

    Args:
      tokens:  [B, S] int32, padded to cfg.max_seq.
      lengths: [B] int32 — actual prompt lengths.

    Returns:
      logits_last: [B, vocab] — logits at each prompt's final position.
      k_cache, v_cache: [L, B, H, S, d_head] — the prompt's KV cache.
    """
    _, s = tokens.shape
    pos = jnp.arange(s)
    x = params["tok_embed"][tokens] + params["pos_embed"][pos][None]
    k_cache = []
    v_cache = []
    for layer in params["layers"]:
        h = _layer_norm(x, layer["ln1_scale"], layer["ln1_bias"])
        q = _split_heads(h @ layer["wq"], cfg)  # [B, H, S, dh]
        k = _split_heads(h @ layer["wk"], cfg)
        v = _split_heads(h @ layer["wv"], cfg)
        attn = prefill_attention(q, k, v, interpret=interpret)
        x = x + _merge_heads(attn, cfg) @ layer["wo"]
        h2 = _layer_norm(x, layer["ln2_scale"], layer["ln2_bias"])
        x = (
            x
            + jax.nn.relu(h2 @ layer["w_up"] + layer["b_up"]) @ layer["w_down"]
            + layer["b_down"]
        )
        k_cache.append(k)
        v_cache.append(v)
    x = _layer_norm(x, params["ln_f_scale"], params["ln_f_bias"])
    logits = x @ params["tok_embed"].T  # tied head: [B, S, vocab]
    last = jnp.clip(lengths - 1, 0, s - 1)
    logits_last = jnp.take_along_axis(logits, last[:, None, None], axis=1)[:, 0]
    return logits_last, jnp.stack(k_cache), jnp.stack(v_cache)


def decode_step(
    params, tokens, positions, k_cache, v_cache, cfg: ModelConfig = CONFIG, interpret=True
):
    """One decode iteration.

    Args:
      tokens:    [B] int32 — the most recently generated token per seq.
      positions: [B] int32 — their positions (= current context length − 1).
      k_cache, v_cache: [L, B, H, S, d_head].

    Returns:
      logits: [B, vocab] for the next token.
      k_cache, v_cache: updated caches.
    """
    _, b, _, s, _ = k_cache.shape
    x = params["tok_embed"][tokens] + params["pos_embed"][positions]  # [B, d]
    new_k_layers = []
    new_v_layers = []
    # One-hot position mask for the cache write: [B, 1, S, 1].
    write_mask = (jnp.arange(s)[None, :] == positions[:, None])[:, None, :, None]
    for li, layer in enumerate(params["layers"]):
        h = _layer_norm(x, layer["ln1_scale"], layer["ln1_bias"])
        q = (h @ layer["wq"]).reshape(b, cfg.n_heads, cfg.d_head)
        k_new = (h @ layer["wk"]).reshape(b, cfg.n_heads, 1, cfg.d_head)
        v_new = (h @ layer["wv"]).reshape(b, cfg.n_heads, 1, cfg.d_head)
        k_li = jnp.where(write_mask, k_new, k_cache[li])
        v_li = jnp.where(write_mask, v_new, v_cache[li])
        attn = decode_attention(q, k_li, v_li, positions + 1, interpret=interpret)
        x = x + attn.reshape(b, cfg.d_model) @ layer["wo"]
        h2 = _layer_norm(x, layer["ln2_scale"], layer["ln2_bias"])
        x = (
            x
            + jax.nn.relu(h2 @ layer["w_up"] + layer["b_up"]) @ layer["w_down"]
            + layer["b_down"]
        )
        new_k_layers.append(k_li)
        new_v_layers.append(v_li)
    x = _layer_norm(x, params["ln_f_scale"], params["ln_f_bias"])
    logits = x @ params["tok_embed"].T
    return logits, jnp.stack(new_k_layers), jnp.stack(new_v_layers)


@functools.lru_cache(maxsize=4)
def cached_params(seed: int = 0):
    return init_params(CONFIG, seed)


def build_prefill_fn(seed: int = 0, interpret: bool = True):
    """Closure over baked weights: (tokens[B,S], lengths[B]) -> outputs."""
    params = cached_params(seed)

    def fn(tokens, lengths):
        return prefill(params, tokens, lengths, CONFIG, interpret)

    return fn


def build_decode_fn(seed: int = 0, interpret: bool = True):
    """Closure over baked weights:
    (tokens[B], positions[B], k_cache, v_cache) -> outputs."""
    params = cached_params(seed)

    def fn(tokens, positions, k_cache, v_cache):
        return decode_step(params, tokens, positions, k_cache, v_cache, CONFIG, interpret)

    return fn
