//! End-to-end benchmarks: simulated serving throughput per scheduler
//! (wall time per simulated run — the harness behind every Fig. 10-21
//! sweep) and real PJRT model step latencies (when artifacts exist).

use andes::experiments::runner::{SchedKind, SimRun};
use andes::model::gpu::a100_4x;
use andes::model::llm::opt_66b;
use andes::runtime::engine::ModelRuntime;
use andes::util::bench::{header, Bencher};
use andes::workload::{ArrivalProcess, Dataset, QoeTrace};

fn main() {
    println!("{}", header());
    let mut b = Bencher::quick();

    // Simulation engine wall-time per 200-request run at overload —
    // the iteration cost of the experiment harness itself.
    for sched in SchedKind::paper_three() {
        let label = format!("sim-200req-overload/{}", sched.label());
        b.bench(&label, || {
            SimRun {
                llm: opt_66b(),
                gpu: a100_4x(),
                sched: sched.clone(),
                dataset: Dataset::ShareGpt,
                arrivals: ArrivalProcess::Poisson { rate: 5.0 },
                qoe_trace: QoeTrace::TextReading,
                num_requests: 200,
                seed: 1,
            }
            .execute()
        });
    }

    // Real model (PJRT) prefill and decode step latency per batch size.
    let dir = ModelRuntime::default_dir();
    if dir.join("meta.json").exists() {
        let runtime = ModelRuntime::load(&dir).expect("load artifacts");
        let prompt: Vec<u32> = (0..64u32).map(|i| 2 + (i % 250)).collect();
        for &batch in &[1usize, 2, 4] {
            let prompts: Vec<Vec<u32>> = (0..batch).map(|_| prompt.clone()).collect();
            b.bench(&format!("pjrt-prefill/b={batch}"), || {
                runtime.prefill(&prompts).unwrap()
            });
        }
        // Decode, stateless API: assemble/extract host copies per call.
        let pre = runtime.prefill(&[prompt.clone()]).unwrap().remove(0);
        for &batch in &[1usize, 4, 8, 16] {
            let entries: Vec<(u32, usize, &[f32], &[f32])> = (0..batch)
                .map(|_| (5u32, 64usize, pre.k_cache.as_slice(), pre.v_cache.as_slice()))
                .collect();
            b.bench(&format!("pjrt-decode-stateless/b={batch}"), || {
                runtime.decode(&entries).unwrap()
            });
        }
        // Decode, steady-state literal-cached path (what the serving
        // engine uses when batch membership is stable).
        for &batch in &[1usize, 8, 16] {
            let m = &runtime.meta;
            let per_seq = m.kv_elems_per_seq();
            let mut k_batch = vec![0f32; batch * per_seq];
            let mut v_batch = vec![0f32; batch * per_seq];
            for row in 0..batch {
                andes::runtime::engine::insert_seq(&mut k_batch, &pre.k_cache, row, batch, m);
                andes::runtime::engine::insert_seq(&mut v_batch, &pre.v_cache, row, batch, m);
            }
            let dims = [
                m.n_layers as i64,
                batch as i64,
                m.n_heads as i64,
                m.max_seq as i64,
                m.d_head as i64,
            ];
            let tokens = vec![5i32; batch];
            let positions = vec![64i32; batch];
            let mut k = xla::Literal::vec1(&k_batch).reshape(&dims).unwrap();
            let mut v = xla::Literal::vec1(&v_batch).reshape(&dims).unwrap();
            b.bench(&format!("pjrt-decode-cached/b={batch}"), || {
                let (logits, k2, v2) = runtime
                    .decode_literals(&tokens, &positions, k.clone(), v.clone(), batch)
                    .unwrap();
                k = k2;
                v = v2;
                logits.len()
            });
        }
    } else {
        println!("(skipping pjrt benches: run `make artifacts`)");
    }
}
