//! Event-calendar and sharded-grid benchmarks.
//!
//! The calendar replaced every per-step next-event scan in the
//! simulation stack, so its register/cancel/pop cycle is paid on every
//! simulated event; this measures those micro-ops, the kind-filtered
//! index query, the shard runner's spawn/merge overhead — and, as the
//! headline case, a one-million-request synthetic trace simulated as an
//! 8-cell grid over [`andes::experiments::shard::run_grid`]. Doubles as
//! the perf regression gate against the committed `BENCH_calendar.json`
//! baseline (>25% mean slowdown fails; bless with `BENCH_BLESS=1`, or
//! automatically when the baseline is missing or provisional).

use andes::backend::sim::SimBackend;
use andes::backend::VirtualClock;
use andes::coordinator::calendar::{EventCalendar, EventKind};
use andes::coordinator::engine::{Engine, EngineConfig};
use andes::experiments::runner::SchedKind;
use andes::experiments::shard::run_grid;
use andes::model::gpu::a100_4x;
use andes::model::latency::LatencyModel;
use andes::model::llm::opt_66b;
use andes::qoe::spec::QoeSpec;
use andes::util::bench::{header, Bencher};
use andes::workload::RequestSpec;

/// A cheap deterministic trace: small prompts and short outputs, paced
/// well below a replica's service rate so the FCFS waiting queue stays
/// shallow and the measurement covers event stepping, not queue sorts.
fn synth_trace(n: usize, seed: u64) -> Vec<RequestSpec> {
    (0..n)
        .map(|i| {
            let j = (i as u64).wrapping_add(seed).wrapping_mul(0x9E37_79B9_7F4A_7C15);
            RequestSpec {
                id: i,
                arrival: i as f64 * 0.25,
                prompt_tokens: 8 + (j % 25) as usize,
                output_tokens: 3 + (j % 7) as usize,
                qoe: QoeSpec::new(1.0, 4.8),
                session: None,
            }
        })
        .collect()
}

/// Run one grid cell: a plain FCFS engine over a synthetic trace,
/// returning the number of requests it finished.
fn run_cell(n: usize, seed: u64) -> usize {
    let llm = opt_66b();
    let gpu = a100_4x();
    let latency = LatencyModel::for_deployment(&llm, &gpu);
    let cfg = EngineConfig {
        kv_capacity_tokens: llm.kv_capacity_tokens(&gpu),
        swap_capacity_tokens: llm.swap_capacity_tokens(&gpu),
        ..EngineConfig::default()
    };
    let mut e = Engine::new(
        cfg,
        SimBackend::new(latency.clone()),
        VirtualClock::default(),
        SchedKind::Fcfs.build(),
        latency,
    );
    e.load_trace(synth_trace(n, seed));
    e.run_to_completion().expect("cell must complete").requests.len()
}

fn main() {
    println!("{}", header());
    let mut b = Bencher::new();

    // Bulk registration: mirror a 10k-request trace onto a fresh
    // calendar, the load_trace hot path.
    b.bench("calendar-register/batch=10k", || {
        let mut cal = EventCalendar::new();
        for i in 0..10_000u64 {
            cal.register(i as f64 * 1e-3, EventKind::Arrival, i);
        }
        cal.len()
    });

    // Steady-state pop-then-register against a 1k-deep timeline — the
    // per-event cost of every simulated arrival.
    let mut cal = EventCalendar::new();
    for i in 0..1024u64 {
        cal.register(i as f64 * 0.5, EventKind::Arrival, i);
    }
    let mut t = 1024.0 * 0.5;
    b.bench("calendar-pop-register/depth=1k", || {
        let w = cal.pop().expect("timeline is kept at depth 1k");
        t += 0.5;
        cal.register(t, EventKind::Arrival, w.payload);
        w.seq
    });

    // Churn with cancellation: two registrations, one cancel, one pop
    // per cycle — the defer-deadline admit/expire pattern.
    let mut cal = EventCalendar::new();
    let mut ct = 0.0f64;
    for i in 0..1024u64 {
        ct += 0.25;
        cal.register(ct, EventKind::DeferDeadline, i);
    }
    b.bench("calendar-churn/register-cancel-pop", || {
        ct += 0.25;
        let a = cal.register(ct, EventKind::DeferDeadline, 1);
        cal.register(ct + 0.1, EventKind::AutoscaleTick, 2);
        cal.cancel(a);
        cal.pop().map(|w| w.seq)
    });

    // Kind-filtered index query over a mixed 4k-wakeup timeline — the
    // gateway/federation `next_defer_deadline` path. The cached per-kind
    // index answers in O(log n); the retained brute-force scan is timed
    // alongside it so the baseline records the speedup it replaced.
    let mut cal = EventCalendar::new();
    let kinds = [
        EventKind::DeferDeadline,
        EventKind::AutoscaleTick,
        EventKind::FederationSync,
        EventKind::DeliveryAck,
    ];
    for i in 0..4096u64 {
        cal.register(i as f64 * 0.01, kinds[(i % 4) as usize], i);
    }
    b.bench("calendar-next-time-of/live=4k", || {
        cal.next_time_of(EventKind::FederationSync)
    });
    b.bench("calendar-next-time-of-scan/live=4k", || {
        cal.next_time_of_scan(EventKind::FederationSync)
    });

    // Shard-runner overhead: spawn, fan out 64 trivial cells over 8
    // workers, merge in cell order.
    b.bench("shard-grid-overhead/cells=64,shards=8", || {
        let cells: Vec<u64> = (0..64).collect();
        let outs = run_grid(&cells, 8, |_, &c| {
            let mut acc = c;
            for i in 0..1000u64 {
                acc = acc.wrapping_add(i * i);
            }
            acc
        });
        outs.iter().sum::<u64>()
    });

    // Headline: a one-million-request trace as an 8-cell sharded grid,
    // every cell a full engine simulation. One timed run — this is the
    // "1M requests in minutes" claim, kept honest by the gate.
    let cells: Vec<u64> = (0..8).collect();
    b.bench_once("grid-sim/requests=1M,cells=8,shards=8", || {
        let outs = run_grid(&cells, 8, |_, &seed| run_cell(125_000, seed));
        let total: usize = outs.iter().sum();
        assert_eq!(total, 1_000_000, "the grid must serve the full 1M-request trace");
        total
    });

    // Perf baseline + regression gate: compare each case's mean against
    // the committed BENCH_calendar.json and fail on >25% slowdowns.
    // Bless (rewrite) the baseline when it is missing, marked
    // `"provisional": true`, or BENCH_BLESS=1 — CI runs this bench
    // twice, so the first pass blesses machine-local numbers and the
    // second gates against them (committed numbers stay provisional
    // because CI hardware differs from any dev box).
    let path = "BENCH_calendar.json";
    let factor = 1.25;
    let bless_forced = std::env::var("BENCH_BLESS").ok().as_deref() == Some("1");
    let baseline = std::fs::read_to_string(path)
        .ok()
        .and_then(|t| andes::util::json::Json::parse(&t).ok());
    let provisional = match &baseline {
        Some(j) => j.get("provisional").as_bool().unwrap_or(false),
        None => true,
    };
    if bless_forced || provisional {
        match std::fs::write(path, b.results_json()) {
            Ok(()) => println!("baseline blessed to {path}"),
            Err(e) => eprintln!("could not write {path}: {e}"),
        }
        return;
    }
    let base = baseline.expect("non-provisional implies a parsed baseline");
    let mut compared = 0usize;
    let mut regressed = 0usize;
    if let Some(cases) = base.get("benchmarks").as_arr() {
        for c in cases {
            let name = match c.get("name").as_str() {
                Some(n) => n.to_string(),
                None => continue,
            };
            let base_mean = match c.get("mean_ns").as_f64() {
                Some(m) if m > 0.0 => m,
                _ => continue,
            };
            let cur = match b.results().iter().find(|r| r.name == name) {
                Some(r) => r,
                None => continue,
            };
            compared += 1;
            let cur_mean = cur.mean.as_nanos() as f64;
            let pct = (cur_mean / base_mean - 1.0) * 100.0;
            if cur_mean > base_mean * factor {
                regressed += 1;
                eprintln!(
                    "REGRESSION {name}: mean {cur_mean:.0} ns vs baseline \
                     {base_mean:.0} ns ({pct:+.1}%)"
                );
            } else {
                println!("gate ok {name}: {cur_mean:.0} ns vs {base_mean:.0} ns ({pct:+.1}%)");
            }
        }
    }
    if compared == 0 {
        eprintln!("baseline {path} shares no cases with this run; re-bless with BENCH_BLESS=1");
        std::process::exit(1);
    }
    if regressed > 0 {
        eprintln!(
            "{regressed} benchmark(s) regressed more than {:.0}% vs {path} \
             (set BENCH_BLESS=1 to re-bless after an intentional change)",
            (factor - 1.0) * 100.0
        );
        std::process::exit(1);
    }
    println!(
        "perf gate: {compared} case(s) within {:.0}% of {path}",
        (factor - 1.0) * 100.0
    );
}
