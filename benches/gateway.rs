//! Gateway hot-path micro-benchmarks.
//!
//! The gateway sits in front of *every* request, so its per-arrival cost
//! must be negligible next to an engine iteration (~150 ms decode). This
//! measures the admission decision against a 16-replica cluster
//! snapshot (tier-blind and tier-weighted), the federation
//! snapshot-merge, the surge detector's observe path, and one pacing
//! round across 10k concurrent streams — reporting admission
//! decisions/sec at the end. Doubles as the perf regression gate: runs
//! are compared against the committed `BENCH_gateway.json` baseline and
//! exit non-zero on a >25% mean slowdown (bless with `BENCH_BLESS=1`,
//! or automatically when the baseline is missing or provisional).

use andes::coordinator::kv::KvCacheManager;
use andes::coordinator::{SlackConfig, SlackEstimator};
use andes::gateway::{
    merge_snapshot, AdmissionConfig, AdmissionController, AutoscaleConfig, LoadMode,
    PacingConfig, PredictiveAutoscaler, ReplicaState, SurgeConfig, SurgeDetector,
    TierWeights, TokenPacer,
};
use andes::qoe::buffer::TokenBuffer;
use andes::qoe::spec::QoeSpec;
use andes::util::bench::{header, Bencher};

fn main() {
    println!("{}", header());
    let mut b = Bencher::new();
    let spec = QoeSpec::new(1.0, 4.8);

    // Admission decision against a 16-replica snapshot, with 10k active
    // requests spread across the cluster.
    let replicas: Vec<ReplicaState> = (0..16)
        .map(|i| ReplicaState {
            active_requests: 625 + i * 3,
            kv_free_tokens: 2_000 + i * 500,
            kv_capacity_tokens: 70_000,
            est_request_tds: 1.2 + i as f64 * 0.1,
        })
        .collect();
    let mut ctl = AdmissionController::new(AdmissionConfig::default());
    b.bench("admission-decide/replicas=16,active=10k", || {
        ctl.decide(250, &spec, &replicas, LoadMode::Surge, 10)
    });

    // Tier-weighted scoring: same decision with non-uniform weights and
    // a rotating tier mix, the federation/`ext-tiers` hot path.
    let mut wctl = AdmissionController::new(AdmissionConfig {
        tier_weights: TierWeights { premium: 2.0, standard: 1.0, economy: 0.5 },
        ..AdmissionConfig::default()
    });
    let tier_specs =
        [QoeSpec::new(0.5, 6.5), QoeSpec::new(1.0, 4.8), QoeSpec::new(2.0, 2.5)];
    let mut tick = 0usize;
    b.bench("admission-decide-weighted/replicas=16", || {
        tick = tick.wrapping_add(1);
        wctl.decide(250, &tier_specs[tick % 3], &replicas, LoadMode::Surge, 10)
    });

    // Federation snapshot merge: fold a 64-admission local ledger into
    // the 16-replica snapshot — paid on every federated decision.
    let ledger: Vec<usize> = (0..64).map(|i| 200 + (i % 7) * 90).collect();
    b.bench("snapshot-merge/replicas=16,ledger=64", || {
        merge_snapshot(&replicas, &ledger)
    });

    // Surge detector: observe + mode with a deep arrival window.
    let mut det = SurgeDetector::new(SurgeConfig::default());
    let mut t = 0.0;
    b.bench("surge-observe", || {
        t += 0.01;
        det.observe(t);
        det.mode()
    });

    // Predictive autoscaler: one planning step against the 16-replica
    // snapshot, with the rate estimate oscillating so both the
    // scale-out and hold paths are exercised.
    let mut asc = PredictiveAutoscaler::new(AutoscaleConfig {
        enabled: true,
        min_replicas: 1,
        max_replicas: 32,
        replica_capacity: 2.0,
        eval_interval_secs: 0.0,
        ..AutoscaleConfig::default()
    });
    let mut at = 0.0;
    b.bench("autoscale-evaluate/replicas=16", || {
        at += 0.05;
        let rate = 2.0 + 30.0 * (1.0 + (at * 0.1).sin()) / 2.0;
        asc.evaluate(at, rate, &replicas, 16)
    });

    // One pacing round over 10k concurrent streams: push a fresh token
    // into every pacer and release whatever is due. The virtual step
    // (0.25 s → 4 tok/s) stays below the release rate (6 tok/s), so the
    // pending queues stay bounded and the measurement covers the
    // steady-state hot path, not queue growth.
    let mut pacers: Vec<TokenPacer> =
        (0..10_000).map(|_| TokenPacer::new(&spec, &PacingConfig::default())).collect();
    let mut now = 0.0;
    b.bench("pacer-round/streams=10k", || {
        now += 0.25;
        let mut released = 0usize;
        for p in pacers.iter_mut() {
            p.push(now);
            released += p.release_due(now);
        }
        released
    });

    // Slack-estimator update: fold one generated token into the
    // pacer-replay digest, then issue the window query the scheduler
    // makes per candidate (DESIGN.md §15) — paid once per generated
    // token when `--slack` is on, so it must stay far below an engine
    // iteration. 1k live streams keep the per-request map realistic.
    let mut est = SlackEstimator::new(SlackConfig::default());
    let mut si = 0usize;
    let mut st = 0.0f64;
    b.bench("slack-estimate/streams=1k", || {
        si = (si + 1) % 1_000;
        st += 0.001;
        est.on_token(si, &spec, st);
        est.window(si, st).unwrap_or(0.0)
    });

    // KV prefix park → claim cycle: the bookkeeping added to every
    // session-turn finish and returning-turn admission (DESIGN.md §10).
    let mut kv = KvCacheManager::new(16 * 4096, 16 * 8192, 16);
    let mut key = 0u64;
    b.bench("kv-park-claim/ctx=512", || {
        key = key.wrapping_add(1);
        kv.allocate(0, 512).expect("fresh alloc");
        kv.park(key % 64, 0).expect("park");
        kv.claim_parked(key % 64).expect("claim")
    });

    // Client-buffer depth probe over a 10k-token stream: guards the
    // binary-search depth_at against regressing to the old O(n) scan
    // (which went quadratic when polled per generated token).
    let mut buf = TokenBuffer::new(&spec);
    for i in 0..10_000 {
        buf.push(i as f64 * 0.01);
    }
    let mut q = 0.0;
    b.bench("buffer-depth/tokens=10k", || {
        q += 0.37;
        if q > 2_200.0 {
            q = 0.0;
        }
        buf.depth_at(q)
    });

    let decisions_per_sec = b
        .results()
        .iter()
        .find(|r| r.name.starts_with("admission-decide"))
        .map(|r| 1.0 / r.mean.as_secs_f64())
        .unwrap_or(0.0);
    println!(
        "\nadmission throughput ≈ {decisions_per_sec:.0} decisions/s \
         (one decode iteration ≈ 150 ms ≈ {:.0} decisions)",
        decisions_per_sec * 0.150
    );

    // Perf baseline + regression gate: compare each case's mean against
    // the committed BENCH_gateway.json and fail on >25% slowdowns.
    // Bless (rewrite) the baseline when it is missing, marked
    // `"provisional": true`, or BENCH_BLESS=1 — CI runs this bench
    // twice, so the first pass blesses machine-local numbers and the
    // second gates against them (committed numbers stay provisional
    // because CI hardware differs from any dev box).
    let path = "BENCH_gateway.json";
    let factor = 1.25;
    let bless_forced = std::env::var("BENCH_BLESS").ok().as_deref() == Some("1");
    let baseline = std::fs::read_to_string(path)
        .ok()
        .and_then(|t| andes::util::json::Json::parse(&t).ok());
    let provisional = match &baseline {
        Some(j) => j.get("provisional").as_bool().unwrap_or(false),
        None => true,
    };
    if bless_forced || provisional {
        match std::fs::write(path, b.results_json()) {
            Ok(()) => println!("baseline blessed to {path}"),
            Err(e) => eprintln!("could not write {path}: {e}"),
        }
        return;
    }
    let base = baseline.expect("non-provisional implies a parsed baseline");
    let mut compared = 0usize;
    let mut regressed = 0usize;
    if let Some(cases) = base.get("benchmarks").as_arr() {
        for c in cases {
            let name = match c.get("name").as_str() {
                Some(n) => n.to_string(),
                None => continue,
            };
            let base_mean = match c.get("mean_ns").as_f64() {
                Some(m) if m > 0.0 => m,
                _ => continue,
            };
            let cur = match b.results().iter().find(|r| r.name == name) {
                Some(r) => r,
                None => continue,
            };
            compared += 1;
            let cur_mean = cur.mean.as_nanos() as f64;
            let pct = (cur_mean / base_mean - 1.0) * 100.0;
            if cur_mean > base_mean * factor {
                regressed += 1;
                eprintln!(
                    "REGRESSION {name}: mean {cur_mean:.0} ns vs baseline \
                     {base_mean:.0} ns ({pct:+.1}%)"
                );
            } else {
                println!("gate ok {name}: {cur_mean:.0} ns vs {base_mean:.0} ns ({pct:+.1}%)");
            }
        }
    }
    if compared == 0 {
        eprintln!("baseline {path} shares no cases with this run; re-bless with BENCH_BLESS=1");
        std::process::exit(1);
    }
    if regressed > 0 {
        eprintln!(
            "{regressed} benchmark(s) regressed more than {:.0}% vs {path} \
             (set BENCH_BLESS=1 to re-bless after an intentional change)",
            (factor - 1.0) * 100.0
        );
        std::process::exit(1);
    }
    println!(
        "perf gate: {compared} case(s) within {:.0}% of {path}",
        (factor - 1.0) * 100.0
    );
}
