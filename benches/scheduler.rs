//! Scheduler micro-benchmarks (criterion-style harness, in-tree).
//!
//! Measures the per-iteration scheduling decision cost — the paper's
//! "negligible overhead" claim (§6.5): the greedy Algorithm 1 must stay
//! far below one decode iteration (~150 ms) even at N = 1000 active
//! requests, while the exact DP (Algorithm 2) is orders of magnitude
//! slower — which is exactly why the paper ships the greedy.

use andes::coordinator::kv::KvCacheManager;
use andes::coordinator::request::{Phase, Request, RequestId};
use andes::coordinator::sched::andes::{AndesConfig, AndesScheduler, KnapsackSolver};
use andes::coordinator::sched::dp::solve_exact_knapsack;
use andes::coordinator::sched::fcfs::FcfsScheduler;
use andes::coordinator::sched::{SchedView, Scheduler};
use andes::model::gpu::a100_4x;
use andes::model::latency::LatencyModel;
use andes::model::llm::opt_66b;
use andes::qoe::spec::QoeSpec;
use andes::util::bench::{header, Bencher};
use andes::util::rng::Rng;

/// Build a saturated scheduling state with `n` active requests
/// (half running, half waiting).
fn build_state(n: usize) -> (Vec<Request>, Vec<RequestId>, KvCacheManager, LatencyModel) {
    let mut rng = Rng::new(42);
    let latency = LatencyModel::for_deployment(&opt_66b(), &a100_4x());
    let mut kv = KvCacheManager::new(70_000, 100_000, 16);
    let mut requests = Vec::with_capacity(n);
    let active: Vec<RequestId> = (0..n).collect();
    for id in 0..n {
        let prompt = rng.range(50, 600);
        let mut r = Request::new(id, rng.f64() * 10.0, prompt, QoeSpec::new(1.0, 4.8));
        if id % 2 == 0 && kv.allocate(id, r.context_len()).is_ok() {
            r.phase = Phase::Running;
            // Mid-stream: some tokens already delivered.
            for k in 0..rng.range(1, 60) {
                r.deliver_token(r.arrival + 1.0 + k as f64 * 0.15);
            }
        }
        requests.push(r);
    }
    (requests, active, kv, latency)
}

fn bench_scheduler(b: &mut Bencher, name: &str, sched: &mut dyn Scheduler, n: usize) {
    let (requests, active, kv, latency) = build_state(n);
    let view = SchedView {
        now: 30.0,
        horizon: 50.0,
        requests: &requests,
        active: &active,
        kv: &kv,
        latency: &latency,
        total_requests_seen: n,
        total_preemptions: 0,
        slack: None,
    };
    b.bench(&format!("{name}/N={n}"), || sched.schedule(&view));
}

fn main() {
    println!("{}", header());
    let mut b = Bencher::new();

    for n in [100, 500, 1000] {
        let mut fcfs = FcfsScheduler::new();
        bench_scheduler(&mut b, "fcfs", &mut fcfs, n);
        let mut andes = AndesScheduler::with_defaults();
        bench_scheduler(&mut b, "andes-greedy", &mut andes, n);
    }
    // The DP is far slower; bench at smaller N only.
    for n in [100, 250] {
        let mut dp = AndesScheduler::new(AndesConfig {
            solver: KnapsackSolver::Dp,
            b_grid: 4,
            ..AndesConfig::default()
        });
        bench_scheduler(&mut b, "andes-dp", &mut dp, n);
    }

    // Raw knapsack kernels.
    let mut rng = Rng::new(7);
    for n in [200usize, 1000] {
        let weights: Vec<usize> = (0..n).map(|_| rng.range(2, 40)).collect();
        let values: Vec<f64> = (0..n).map(|_| rng.f64()).collect();
        b.bench(&format!("knapsack-dp-solve/N={n}"), || {
            solve_exact_knapsack(&weights, &values, n / 4, 2000)
        });
    }

    // Paper claim: greedy decision ≪ decode iteration (~150 ms).
    let budget_ns = 150_000_000u128;
    let worst = b
        .results()
        .iter()
        .filter(|r| r.name.starts_with("andes-greedy"))
        .map(|r| r.mean.as_nanos())
        .max()
        .unwrap_or(0);
    println!(
        "\nandes-greedy worst mean = {:.2} ms vs decode iteration ~150 ms → {}",
        worst as f64 / 1e6,
        if worst * 10 < budget_ns { "NEGLIGIBLE (paper claim holds)" } else { "SIGNIFICANT" }
    );
}
